"""Pallas flash-attention kernel vs jnp oracles (interpret mode), with
shape/dtype sweeps, plus the manual-backward XLA implementation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_chunked, attention_naive
from repro.kernels.flash_attention.xla import flash_attention_xla

SWEEP = [
    # (B, Sq, Skv, H, K, D, causal, dtype)
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 256, 8, 8, 32, True, jnp.bfloat16),
    (2, 128, 256, 4, 1, 64, False, jnp.float32),
    (1, 512, 512, 2, 2, 128, True, jnp.float32),
]


def _qkv(shape_spec, key):
    B, Sq, Skv, H, K, D, causal, dt = shape_spec
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dt)
    k = jax.random.normal(ks[1], (B, Skv, K, D), dt)
    v = jax.random.normal(ks[2], (B, Skv, K, D), dt)
    return q, k, v


@pytest.mark.parametrize("spec", SWEEP)
def test_pallas_fwd_matches_naive(spec):
    *_, causal, dt = spec
    q, k, v = _qkv(spec, jax.random.PRNGKey(0))
    ref = attention_naive(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("spec", SWEEP[:2])
def test_chunked_oracle_matches_naive(spec):
    *_, causal, dt = spec
    q, k, v = _qkv(spec, jax.random.PRNGKey(1))
    ref = attention_naive(q, k, v, causal=causal)
    out = attention_chunked(q, k, v, causal=causal, q_chunk=64, kv_chunk=64)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [None, 64])
def test_flash_xla_grads_match_naive(window):
    B, S, H, K, D = 2, 128, 4, 2, 32
    key = jax.random.PRNGKey(2)
    q, k, v = _qkv((B, S, S, H, K, D, True, jnp.float32), key)
    co = jax.random.normal(jax.random.fold_in(key, 9), (B, S, H, D))

    def naive(q, k, v):
        G = H // K
        s = jnp.einsum("bqkgd,bskd->bkgqs", q.reshape(B, S, K, G, D), k) * (D**-0.5)
        qp, kp = jnp.arange(S), jnp.arange(S)
        m = kp[None, :] <= qp[:, None]
        if window:
            m &= kp[None, :] > qp[:, None] - window
        s = jnp.where(m[None, None, None], s, -2e38)
        return jnp.einsum("bkgqs,bskv->bqkgv", jax.nn.softmax(s, -1),
                          v).reshape(B, S, H, D)

    g1 = jax.grad(lambda *a: (flash_attention_xla(*a, True, window, 64, 64)
                              * co).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (naive(*a) * co).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


def test_pallas_grad_path_runs():
    q, k, v = _qkv((1, 128, 128, 4, 4, 32, True, jnp.float32),
                   jax.random.PRNGKey(3))
    g = jax.grad(lambda *a: flash_attention(*a, causal=True, block_q=64,
                                            block_k=64, interpret=True).sum(),
                 argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert np.isfinite(np.asarray(x)).all()
