"""Partition rules: coverage and divisibility over every arch's param tree,
plus batch/cache specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as cfgs
from repro.models import SHAPES, build
from repro.sharding import specs as sspecs

AXES3 = ("pod", "data", "model")
MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def _iter_specs(tree, spec_tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs)
    return [(sspecs.path_str(p), l, s) for (p, l), s in zip(leaves, specs)]


@pytest.mark.parametrize("arch", cfgs.ARCH_IDS)
def test_param_specs_rank_and_coverage(arch):
    cfg = cfgs.get(arch)
    api = build(cfg)
    tree = api.param_specs()
    spec_tree = sspecs.tree_partition_specs(tree, AXES3)
    n_sharded = 0
    for path, leaf, spec in _iter_specs(tree, spec_tree):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        if any(s is not None for s in spec):
            n_sharded += 1
    # the overwhelming majority of parameter BYTES must be sharded
    total = sum(l.size for _, l, _ in _iter_specs(tree, spec_tree))
    sharded = sum(
        l.size for _, l, s in _iter_specs(tree, spec_tree)
        if any(x is not None for x in s))
    assert sharded / total > 0.99, f"{arch}: only {sharded/total:.2%} sharded"


@pytest.mark.parametrize("arch", ["qwen3_1p7b", "llama4_maverick_400b_a17b",
                                  "jamba_1p5_large_398b"])
def test_param_specs_mostly_divisible(arch):
    """Sharded dims should be divisible by their mesh axes for the big
    tensors (uneven shards compile but waste memory via padding)."""
    cfg = cfgs.get(arch)
    api = build(cfg)
    tree = api.param_specs()
    spec_tree = sspecs.tree_partition_specs(tree, AXES3)
    bad_bytes = total = 0
    for path, leaf, spec in _iter_specs(tree, spec_tree):
        total += leaf.size
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            k = 1
            for a in axes:
                k *= MESH_SIZES[a]
            if dim % k:
                bad_bytes += leaf.size
                break
    assert bad_bytes / max(total, 1) < 0.02, f"{arch}: {bad_bytes/total:.2%} padded"


def test_batch_specs():
    b = {"tokens": jnp.zeros((8, 16), jnp.int32),
         "cache_index": jnp.zeros((), jnp.int32)}
    out = sspecs.batch_partition_specs(b, AXES3)
    assert out["tokens"] == P(("pod", "data"), None)
    assert out["cache_index"] == P()


def test_cache_specs_shard_batch_or_seq():
    cfg = cfgs.get("llama3p2_1b")
    api = build(cfg)
    cache = jax.eval_shape(lambda: api.make_caches(128, 1024))
    specs = sspecs.cache_partition_specs(cache, AXES3, global_batch=128,
                                         dp_size=32)
    flat = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(any(s is not None for s in sp) for sp in flat)
    # B=1 long-context: sequence must carry the sharding instead
    specs1 = sspecs.cache_partition_specs(cache, AXES3, global_batch=1,
                                          dp_size=32)
    flat1 = jax.tree_util.tree_leaves(specs1, is_leaf=lambda x: isinstance(x, P))
    assert any(sp[2] is not None for sp in flat1 if len(sp) >= 3)


def test_hints_noop_without_mesh_context():
    from repro.sharding.hints import shard_hint

    x = jnp.ones((4, 8, 16))
    assert shard_hint(x, "activations") is x
