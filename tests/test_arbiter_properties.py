"""Property-based contract for the fair-share arbiter.

The arbiter is the one piece of the farm whose correctness is a pure
function — so instead of example tests, this file pins its *laws* over
randomized inputs:

- **demand cap** — a job never holds more services than unfinished tasks;
- **well-formedness** — assignments only mention real services/jobs;
- **determinism** — same inputs, same answer, always;
- **fixpoint / movement minimization** — feeding the arbiter its own
  output returns it unchanged: a steady-state rebalance moves nothing;
- **reference match** — the heap-based production solver agrees exactly
  with an independent straightforward re-derivation of the canonical-
  bundle spec (max-deficit greedy, linear scan);
- **incremental == full** — :class:`IncrementalArbiter` fed any
  join/leave event sequence answers byte-identically to a fresh
  ``fair_assignment``, without ever re-sorting its service order.

The laws run twice: a seeded ``random`` sweep that always runs, and a
``hypothesis`` version (with shrinking) that skips itself when the
optional dependency is absent (the ``test`` extra installs it in CI).
"""

import random

import pytest

from repro.farm import fair_assignment
from repro.farm.arbiter import IncrementalArbiter

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when extra missing
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (test extra)")

CAP_CLASSES = (0.25, 0.5, 1.0, 2.0)


# ------------------------------------------------------------------ #
# an independent re-derivation of the spec (no heap, no caches): walk
# services from largest capacity, give each to the max-deficit job
# (admission order breaks ties), then keep incumbents filling canonical
# slots and fill the rest preferring each service's own pairing.
# ------------------------------------------------------------------ #
def reference_assignment(capacities, jobs, current=None):
    current = current or {}
    jobs = [(j, w, d) for j, w, d in jobs if d is None or d > 0]
    if not jobs or not capacities:
        return {}
    by_cap = sorted(capacities, key=lambda s: (-capacities[s], s))
    total_cap = sum(capacities.values())
    total_w = sum(w for _, w, _ in jobs) or 1.0
    target = {j: total_cap * w / total_w for j, w, _ in jobs}
    demand = {j: d for j, _, d in jobs}
    order = {j: i for i, (j, _, _) in enumerate(jobs)}
    alloc = {j: 0.0 for j, _, _ in jobs}
    count = {j: 0 for j, _, _ in jobs}
    canonical, need = {}, {}
    for sid in by_cap:
        best = None
        for j in alloc:
            if demand[j] is not None and count[j] >= demand[j]:
                continue
            key = (-(target[j] - alloc[j]), order[j])
            if best is None or key < best[0]:
                best = (key, j)
        if best is None:
            break
        j = best[1]
        canonical[sid] = j
        key = (capacities[sid], j)
        need[key] = need.get(key, 0) + 1
        alloc[j] += capacities[sid]
        count[j] += 1
    assign = {}
    for sid in by_cap:
        j = current.get(sid)
        if j is not None and need.get((capacities[sid], j), 0) > 0:
            assign[sid] = j
            need[(capacities[sid], j)] -= 1
    for sid in by_cap:
        if sid in assign:
            continue
        cap = capacities[sid]
        j = canonical.get(sid)
        if j is None or need.get((cap, j), 0) <= 0:
            cands = [k for k in alloc if need.get((cap, k), 0) > 0]
            if not cands:
                continue
            j = min(cands, key=lambda k: order[k])
        assign[sid] = j
        need[(cap, j)] -= 1
    return assign


# ------------------------------------------------------------------ #
# the laws, checked on one (capacities, jobs, current) case
# ------------------------------------------------------------------ #
def check_laws(capacities, jobs, current, rng):
    out = fair_assignment(capacities, jobs, current)

    # well-formedness
    job_ids = {j for j, _, _ in jobs}
    assert set(out) <= set(capacities)
    assert set(out.values()) <= job_ids

    # demand cap
    for j, _w, d in jobs:
        held = sum(1 for v in out.values() if v == j)
        if d is not None:
            assert held <= d, f"job {j} holds {held} > demand {d}"

    # determinism
    assert fair_assignment(dict(capacities), list(jobs), dict(current)) \
        == out

    # fixpoint: the arbiter's own output is a no-op rebalance
    assert fair_assignment(capacities, jobs, out) == out

    # reference match (production heap solver vs straightforward spec)
    assert reference_assignment(capacities, jobs, current) == out

    # incremental == full, under a shuffled join order plus departures
    arb = IncrementalArbiter()
    extra = [f"ghost{i}" for i in range(rng.randrange(0, 3))]
    joined = list(capacities) + extra
    rng.shuffle(joined)
    for sid in joined:
        arb.service_joined(sid, capacities.get(sid, 1.0))
    for sid in extra:
        arb.service_left(sid)
    assert arb.compute(jobs, current) == out
    assert arb.resorts == 0, "event-maintained order must never re-sort"

    # changes to already-non-binding demands (d >= pool size: the job
    # could never hold that many services) are invisible: memo hit,
    # identical answer
    n = len(capacities)
    if n > 0 and all(d is None or d >= n for _, _, d in jobs):
        bumped = [(j, w, None if d is None else d + 1) for j, w, d in jobs]
        hits = arb.memo_hits
        assert arb.compute(bumped, out) == out
        assert arb.memo_hits == hits + 1
    return out


def random_case(rng):
    n_services = rng.randrange(0, 13)
    capacities = {f"s{i:02d}": rng.choice(CAP_CLASSES)
                  for i in range(n_services)}
    n_jobs = rng.randrange(0, 5)
    jobs = [(f"j{i}", rng.choice((0.5, 1.0, 2.0)),
             rng.choice((None, 0, 1, 2, 5, 15)))
            for i in range(n_jobs)]
    # incumbent maps include stale jobs (finished but not yet revoked)
    current = {sid: rng.choice([f"j{k}" for k in range(n_jobs + 1)])
               for sid in capacities if rng.random() < 0.5}
    return capacities, jobs, current


def test_arbiter_laws_seeded_sweep():
    """The always-on sweep: 400 randomized cases across pool shapes,
    weights, demands and stale incumbents."""
    rng = random.Random(0xA121)
    for _ in range(400):
        capacities, jobs, current = random_case(rng)
        check_laws(capacities, jobs, current, rng)


def test_demand_only_churn_never_resorts_or_resolves():
    """A closed job counting down a huge demand must not disturb the
    arbiter at all: sorted order untouched AND every rebalance after the
    first is a memo hit (the normalized inputs are unchanged)."""
    arb = IncrementalArbiter()
    for i in range(50):
        arb.service_joined(f"s{i:02d}", 1.0)
    jobs = [("a", 1.0, 100_000), ("b", 1.0, None)]
    out = arb.compute(jobs, {})
    solves = arb.solves
    for d in range(100_000, 99_000, -100):  # 10 demand-only events
        out = arb.compute([("a", 1.0, d), ("b", 1.0, None)], out)
    assert arb.solves == solves, "demand-only churn must hit the memo"
    assert arb.memo_hits >= 10
    assert arb.resorts == 0


def test_membership_churn_never_resorts():
    """500 random join/leave events maintain the capacity-sorted order
    by bisection — the full re-sort counter stays at zero and every
    answer still matches a fresh ``fair_assignment``."""
    rng = random.Random(7)
    arb = IncrementalArbiter()
    live = {}
    jobs = [("a", 1.0, None), ("b", 2.0, None)]
    out = {}
    for i in range(500):
        if live and rng.random() < 0.4:
            sid = rng.choice(sorted(live))
            del live[sid]
            arb.service_left(sid)
        else:
            sid = f"s{i:03d}"
            live[sid] = rng.choice(CAP_CLASSES)
            arb.service_joined(sid, live[sid])
        if rng.random() < 0.2:
            out = arb.compute(jobs, out)
            assert out == fair_assignment(live, jobs, out)
    assert arb.resorts == 0


def test_fixpoint_is_exactly_movement_free():
    """On a heterogeneous pool with binding demands, re-arbitrating the
    standing assignment revokes nothing (the scheduler relies on this:
    steady-state rebalances are free)."""
    capacities = {f"s{i}": c for i, c in
                  enumerate((1.0, 1.0, 0.5, 0.5, 0.25, 2.0, 1.0))}
    jobs = [("a", 1.0, 3), ("b", 1.0, None), ("c", 2.0, 2)]
    out = fair_assignment(capacities, jobs)
    for _ in range(5):
        nxt = fair_assignment(capacities, jobs, out)
        assert nxt == out


if HAVE_HYPOTHESIS:
    sids = st.integers(min_value=0, max_value=12)
    caps_st = st.dictionaries(
        st.integers(0, 30).map(lambda i: f"s{i:02d}"),
        st.sampled_from(CAP_CLASSES), max_size=13)
    jobs_st = st.lists(
        st.tuples(st.sampled_from(["j0", "j1", "j2", "j3"]),
                  st.sampled_from([0.5, 1.0, 2.0]),
                  st.sampled_from([None, 0, 1, 2, 5, 15])),
        max_size=4, unique_by=lambda t: t[0])
    seeds_st = st.integers(min_value=0, max_value=2**32 - 1)

    @needs_hypothesis
    @settings(max_examples=200, deadline=None)
    @given(caps=caps_st, jobs=jobs_st, seed=seeds_st)
    def test_arbiter_laws_hypothesis(caps, jobs, seed):
        rng = random.Random(seed)
        job_pool = [j for j, _, _ in jobs] + ["jX"]
        current = {sid: rng.choice(job_pool)
                   for sid in caps if rng.random() < 0.5}
        check_laws(caps, jobs, current, rng)
