"""Integration: the dry-run machinery on a small placeholder fleet
(subprocess so the 1-device smoke environment is untouched)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], capture_output=True,
        text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def test_lower_compile_analyze_small_mesh():
    stdout = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        import repro.launch.dryrun as dr
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        lowered, meta = dr.build_lowering(
            "whisper_tiny", "train_4k", mesh,
            batch_override=8, train_overrides={"remat": True})
        rec = dr.analyze(lowered, mesh=mesh)
        assert rec["memory"]["peak_bytes_per_device"] > 0
        assert rec["hlo_dot_flops_per_device"] > 0
        assert rec["collectives"]["total_wire_bytes"] > 0
        print("TRAIN_OK", json.dumps(rec["collectives"]["count"]))

        lowered, meta = dr.build_lowering("llama3p2_1b", "decode_32k", mesh,
                                          batch_override=8)
        rec = dr.analyze(lowered, mesh=mesh)
        assert rec["memory"]["peak_bytes_per_device"] > 0
        print("DECODE_OK")
    """)
    assert "TRAIN_OK" in stdout and "DECODE_OK" in stdout


def test_multi_pod_axis_shards():
    stdout = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax
        import repro.launch.dryrun as dr
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
        lowered, meta = dr.build_lowering(
            "qwen3_1p7b", "train_4k", mesh, batch_override=8,
            train_overrides={"remat": True})
        rec = dr.analyze(lowered, mesh=mesh)
        # the pod axis must appear in the collective schedule (grad sync)
        assert rec["collectives"]["total_wire_bytes"] > 0
        print("MULTIPOD_OK")
    """)
    assert "MULTIPOD_OK" in stdout
