"""Normal-form payoff: a fused farm(seq(f3∘f2∘f1)) vs a staged pipeline
(three dispatches + host transfers per task) — the JJPF pre-processing
measured as dispatch-count/latency reduction."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (BasicClient, LookupService, Pipe, Program, Seq,
                        Service, interpret, normalize)

N_TASKS = 64
DIM = 256


def _stage(i):
    w = jax.random.normal(jax.random.PRNGKey(i), (DIM, DIM)) * 0.05
    return Program(lambda x, w=w: jnp.tanh(x @ w), name=f"stage{i}")


def bench() -> list[tuple[str, float, str]]:
    stages = [_stage(i) for i in range(3)]
    skel = Pipe(Seq(stages[0]), Seq(stages[1]), Seq(stages[2]))
    tasks = [jax.random.normal(jax.random.PRNGKey(100 + i), (DIM,))
             for i in range(N_TASKS)]

    # staged execution: one jitted call per stage per task (3N dispatches)
    fns = [jax.jit(p.fn) for p in stages]
    for f in fns:
        jax.block_until_ready(f(tasks[0]))  # compile
    t0 = time.perf_counter()
    staged = tasks
    for f in fns:
        staged = [f(t) for t in staged]
    jax.block_until_ready(staged)
    dt_staged = time.perf_counter() - t0

    # normal form: ONE jitted fused program per task (N dispatches)
    nf = normalize(skel)
    fused = jax.jit(nf.worker.program.fn)
    jax.block_until_ready(fused(tasks[0]))
    t0 = time.perf_counter()
    out = [fused(t) for t in tasks]
    jax.block_until_ready(out)
    dt_fused = time.perf_counter() - t0

    import numpy as np

    ref = interpret(skel, tasks[:4])
    for a, b in zip(out[:4], ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    return [
        ("normal_form/staged_3_dispatches", dt_staged * 1e6 / N_TASKS, ""),
        ("normal_form/fused_1_dispatch", dt_fused * 1e6 / N_TASKS,
         f"speedup={dt_staged/dt_fused:.2f}x"),
    ]


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
