"""Recorder overhead gate + the Perfetto trace artifact.

The telemetry spine (``repro.obs``) promises *low-overhead* tracing: the
hot path appends one tuple per batch to a per-thread ring (no shared
lock) and folds latencies into fixed-bucket histograms.  This benchmark
is the regression gate for that promise, on the batched inproc
configuration ``engine_overhead`` uses, tightened to **1 ms per batch**
(ten times faster tasks than that gate: short enough that scheduler +
recorder cost is a visible share of the per-task figure, long enough
that the ≤3% ceiling is meaningful for real workloads):

- **baseline** — BasicClient over N in-process services, tracing
  disabled (``obs=None``: the dispatch path carries no recorder code);
- **traced** — the identical workload with a full ``Observability``
  bundle attached (ring events + all four standard histograms).

The report also carries ``dispatch_overhead_us_per_task`` — the raw
µs/task the recorder adds (traced − baseline), the number to watch if
the percentage gate ever saturates.

Each path runs ``--repeats`` times interleaved and the *minima* are
compared (load spikes inflate means, never minima); the GC is off for
the measured region like the other overhead gates.  The gate: traced
µs/task ≤ ``OVERHEAD_CEILING_PCT`` (3%) over baseline.  Rounds are
re-added while the ratio fails, up to a retry budget — a real
regression keeps failing, noise converges.

The second half replays the paper's heterogeneous-NoW scenario
(``benchmarks/heterogeneous_now.py``'s 1,1,2,4 mix, seeded ``sim://``)
with a recorder attached and exports the Chrome trace-event JSON —
the artifact that loads in Perfetto with one track per service and
task spans nested under leases.  Both land in CI: ``BENCH_obs.json``
(the gate numbers) and ``BENCH_obs_trace.json`` (the trace).
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (BasicClient, Farm, LookupService, Program,  # noqa: E402
                        Seq, Service, interpret)
from repro.obs import Observability  # noqa: E402
from repro.obs.export import (export_chrome_trace,  # noqa: E402
                              validate_chrome_trace)
from repro.sim import SimCluster  # noqa: E402

PROGRAM = Program(lambda x: x * 3.0 + 1.0, name="affine", jit=False)

OVERHEAD_CEILING_PCT = 3.0  # traced µs/task over tracing-disabled
TASK_MS = 1.0               # per-batch service delay (fast-task regime)


def _cluster(n_services):
    lookup = LookupService()
    for i in range(n_services):
        # 1 ms per *batch*: fast tasks, so the scheduler's own per-task
        # cost (and any recorder regression on it) stays visible in the
        # total instead of hiding under long sleeps
        Service(lookup, task_delay_s=TASK_MS / 1e3,
                service_id=f"s{i}").start()
    return lookup


def run_once(n_services, n_tasks, knobs, reference, obs) -> float:
    lookup = _cluster(n_services)
    tasks = [float(i) for i in range(n_tasks)]
    out: list = []
    t0 = time.perf_counter()
    BasicClient(PROGRAM, None, tasks, out, lookup=lookup, obs=obs,
                **knobs).compute(timeout=600)
    dt = time.perf_counter() - t0
    got = [float(v) for v in out]
    assert got == reference, "output diverges from interpret()"
    return dt


def bench_overhead(*, n_services: int = 4, n_tasks: int = 20_000,
                   max_batch: int = 16, repeats: int = 3,
                   ceiling_pct: float = OVERHEAD_CEILING_PCT) -> dict:
    knobs = dict(max_batch=max_batch, max_inflight=2,
                 adaptive_batching=False, speculation=False)
    reference = [float(v) for v in
                 interpret(Farm(Seq(PROGRAM)),
                           [float(i) for i in range(n_tasks)])]

    # warm-up, discarded: the first full-size run in a process is
    # reproducibly slower (allocator/thread warmup) — charge it to
    # neither path
    run_once(n_services, n_tasks, knobs, reference, None)
    run_once(n_services, n_tasks, knobs, reference, Observability())

    times: dict[str, list[float]] = {"baseline": [], "traced": []}

    def measure_round(n: int) -> None:
        for _ in range(n):  # interleaved: drift hits both paths equally
            times["baseline"].append(
                run_once(n_services, n_tasks, knobs, reference, None))
            times["traced"].append(
                run_once(n_services, n_tasks, knobs, reference,
                         Observability()))

    gc.disable()
    try:
        measure_round(repeats)
        for _ in range(2):
            if (min(times["traced"]) / min(times["baseline"]) - 1.0) \
                    * 100.0 <= ceiling_pct:
                break
            measure_round(repeats)
    finally:
        gc.enable()

    base_s = min(times["baseline"])
    traced_s = min(times["traced"])
    overhead_pct = (traced_s / base_s - 1.0) * 100.0
    # one traced run for the event-volume telemetry in the report
    obs = Observability()
    run_once(n_services, n_tasks, knobs, reference, obs)
    return {
        "benchmark": "observability",
        "config": {"n_services": n_services, "n_tasks": n_tasks,
                   "task_ms": TASK_MS, "max_batch": max_batch,
                   "repeats": repeats},
        "baseline_us_per_task": base_s * 1e6 / n_tasks,
        "traced_us_per_task": traced_s * 1e6 / n_tasks,
        "dispatch_overhead_us_per_task": (traced_s - base_s) * 1e6
        / n_tasks,
        "overhead_pct": overhead_pct,
        "ceiling_pct": ceiling_pct,
        "events_per_run": obs.recorder.stats()["events_recorded"],
        "pass": overhead_pct <= ceiling_pct,
        "outputs": "identical",
    }


def export_hetero_trace(path: str, *, seed: int = 7, n_tasks: int = 240,
                        max_batch: int = 8) -> dict:
    """Replay the heterogeneous-NoW scenario (1,1,2,4 mix) with a
    recorder attached and export the Chrome trace — the Perfetto
    artifact the acceptance gate loads."""
    obs = Observability()
    tasks = [float(i) for i in range(n_tasks)]
    with SimCluster(speed_factors=[1.0, 1.0, 2.0, 4.0], seed=seed,
                    base_cost_s=0.001, latency_s=0.0001,
                    latency_jitter_s=0.00001, obs=obs) as cluster:
        cluster.run(PROGRAM, tasks, max_batch=max_batch, max_inflight=2,
                    lease_s=5.0)
    export_chrome_trace(obs, path)
    return validate_chrome_trace(path)


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table) — smoke sizes."""
    r = bench_overhead(n_tasks=8000, repeats=2)
    return [
        ("observability/baseline", r["baseline_us_per_task"],
         "tracing disabled"),
        ("observability/traced", r["traced_us_per_task"],
         f"overhead={r['overhead_pct']:+.2f}% "
         f"events={r['events_per_run']}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=20_000)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--ceiling-pct", type=float,
                    default=OVERHEAD_CEILING_PCT,
                    help="max tolerated traced-vs-disabled overhead")
    ap.add_argument("--out", default=None,
                    help="write results to this JSON file "
                         "(e.g. BENCH_obs.json)")
    ap.add_argument("--trace-out", default=None,
                    help="export the heterogeneous-NoW Chrome trace to "
                         "this path (e.g. BENCH_obs_trace.json)")
    args = ap.parse_args(argv)

    result = bench_overhead(n_services=args.services, n_tasks=args.tasks,
                            max_batch=args.max_batch,
                            repeats=args.repeats,
                            ceiling_pct=args.ceiling_pct)
    print(f"observability/baseline,{result['baseline_us_per_task']:.2f},"
          f"tracing disabled")
    print(f"observability/traced,{result['traced_us_per_task']:.2f},"
          f"overhead={result['overhead_pct']:+.2f}% "
          f"events={result['events_per_run']}")

    if args.trace_out:
        info = export_hetero_trace(args.trace_out)
        result["trace"] = dict(info, path=args.trace_out)
        print(f"wrote {args.trace_out} ({info['events']} trace events, "
              f"{info['service_tracks']} service tracks, "
              f"{len(info['event_types'])} event types)")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    assert result["pass"], (
        f"recorder overhead {result['overhead_pct']:.2f}% exceeds the "
        f"{args.ceiling_pct}% ceiling over the tracing-disabled path")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
