"""Paper claim: 'load balancing is guaranteed across the recruited
computational resources, even in case of resources with fairly different
computing capabilities' — pull scheduling on a 4x-heterogeneous cluster."""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import BasicClient, LookupService, Program, Service

N_TASKS = 60


def bench() -> list[tuple[str, float, str]]:
    lookup = LookupService()
    # speeds 1x, 1x, 2x-slower, 4x-slower
    delays = [0.004, 0.004, 0.008, 0.016]
    for i, d in enumerate(delays):
        Service(lookup, task_delay_s=d, service_id=f"svc-{i}x{d*1e3:.0f}ms").start()
    out: list = []
    tasks = [jnp.asarray(float(i)) for i in range(N_TASKS)]
    t0 = time.perf_counter()
    cm = BasicClient(Program(lambda x: x * 2), None, tasks, out,
                     lookup=lookup, speculation=False)
    cm.compute(timeout=600)
    dt = time.perf_counter() - t0
    per = cm.stats()["per_service"]
    # ideal static split = 15 each; pull scheduling should give the fast
    # nodes ~2x the work of the 2x-slower node
    fast = sum(v for k, v in per.items() if "4ms" in k)
    slow = sum(v for k, v in per.items() if "16ms" in k)
    imbalance = max(per.values()) / max(min(per.values()), 1)
    return [("load_balance/heterogeneous_4x", dt * 1e6 / N_TASKS,
             f"fast={fast} slow={slow} per={per}")]


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
