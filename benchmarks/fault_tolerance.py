"""Paper claim: 'the execution of a parallel program can transparently
resist to node or network faults' — overhead of killing 25-50% of the
services mid-run vs a fault-free run.

``--kill-real`` upgrades the claim from simulation to reality: services
are separate OS processes (``proc://`` transport via
``repro.launch.now.NowPool``) and one of them is SIGKILLed *while it holds
leased tasks*.  The farm must still return every result — the dropped
connection raises ``ServiceFailure`` in that control thread, the leases
fail back to the repository, and the surviving workers pull them."""

from __future__ import annotations

import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import BasicClient, LookupService, Program, Service

N_TASKS = 40
TASK_S = 0.008


def run(kill: int) -> tuple[float, dict]:
    lookup = LookupService()
    services = [Service(lookup, task_delay_s=TASK_S, service_id=f"s{i}")
                for i in range(4)]
    for s in services:
        s.start()
    for s in services[:kill]:
        s.fail_after(2)
    out: list = []
    tasks = [jnp.asarray(float(i)) for i in range(N_TASKS)]
    t0 = time.perf_counter()
    cm = BasicClient(Program(lambda x: x + 1), None, tasks, out,
                     lookup=lookup, lease_s=5.0)
    cm.compute(timeout=600)
    assert len(out) == N_TASKS and all(v is not None for v in out)
    return time.perf_counter() - t0, cm.stats()


def bench() -> list[tuple[str, float, str]]:
    rows = []
    base, _ = run(0)
    for kill in (1, 2):
        dt, stats = run(kill)
        rows.append((f"fault_tolerance/kill={kill}of4", dt * 1e6 / N_TASKS,
                     f"overhead={dt/base-1:+.1%} "
                     f"reschedules={stats['reschedules']} complete=100%"))
    return rows


def run_kill_real(n_workers: int = 3, n_tasks: int = 60
                  ) -> tuple[str, float, str]:
    """SIGKILL a live worker process mid-run; every task still completes."""
    from repro.launch.now import NowPool

    lookup = LookupService()
    with NowPool(n_workers, lookup, task_delay_s=0.02,
                 service_prefix="w") as pool:
        victim = pool.workers[0].service_id
        out: list = []
        tasks = [jnp.asarray(float(i)) for i in range(n_tasks)]
        cm = BasicClient(Program(lambda x: x + 1, name="inc"), None, tasks,
                         out, lookup=lookup, lease_s=5.0, speculation=False)
        killed: dict = {}

        def killer():
            # SIGKILL only once the victim demonstrably holds work —
            # killing a worker that is still importing jax proves nothing
            while not cm.repository.all_done:
                done = cm.repository.stats()["per_service"].get(victim, 0)
                if done >= 2:
                    pool.kill(0)  # SIGKILL: no goodbye, sockets just die
                    killed["after_tasks"] = done
                    return
                time.sleep(0.01)

        threading.Thread(target=killer, daemon=True).start()
        t0 = time.perf_counter()
        cm.compute(timeout=600)
        dt = time.perf_counter() - t0
        assert "after_tasks" in killed, "victim finished before the kill"
        assert not pool.workers[0].alive, "victim survived SIGKILL?"
        got = [float(v) for v in out]
        assert got == [i + 1.0 for i in range(n_tasks)], \
            "results wrong/missing after real worker death"
        stats = cm.stats()
    return (f"fault_tolerance/kill_real={victim}of{n_workers}procs",
            dt * 1e6 / n_tasks,
            f"SIGKILL@{killed['after_tasks']}tasks "
            f"reschedules={stats['reschedules']} complete=100%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kill-real", action="store_true",
                    help="SIGKILL a real worker process mid-run (proc "
                         "transport) instead of the simulated fault table")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--tasks", type=int, default=60)
    args = ap.parse_args()
    rows = ([run_kill_real(args.workers, args.tasks)] if args.kill_real
            else bench())
    for r in rows:
        print(",".join(str(x) for x in r))
