"""Paper claim: 'the execution of a parallel program can transparently
resist to node or network faults' — overhead of killing 25-50% of the
services mid-run vs a fault-free run."""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import BasicClient, LookupService, Program, Service

N_TASKS = 40
TASK_S = 0.008


def run(kill: int) -> tuple[float, dict]:
    lookup = LookupService()
    services = [Service(lookup, task_delay_s=TASK_S, service_id=f"s{i}")
                for i in range(4)]
    for s in services:
        s.start()
    for s in services[:kill]:
        s.fail_after(2)
    out: list = []
    tasks = [jnp.asarray(float(i)) for i in range(N_TASKS)]
    t0 = time.perf_counter()
    cm = BasicClient(Program(lambda x: x + 1), None, tasks, out,
                     lookup=lookup, lease_s=5.0)
    cm.compute(timeout=600)
    assert len(out) == N_TASKS and all(v is not None for v in out)
    return time.perf_counter() - t0, cm.stats()


def bench() -> list[tuple[str, float, str]]:
    rows = []
    base, _ = run(0)
    for kill in (1, 2):
        dt, stats = run(kill)
        rows.append((f"fault_tolerance/kill={kill}of4", dt * 1e6 / N_TASKS,
                     f"overhead={dt/base-1:+.1%} "
                     f"reschedules={stats['reschedules']} complete=100%"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
