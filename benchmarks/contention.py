"""Repository lock contention: 1 vs 8 vs 32 shards under real threads.

The sharded-repository gate.  Everything else in the scale suite runs on
the deterministic ``sim://`` clock, which *serializes* the engine by
construction and therefore cannot see lock contention at all — so this
benchmark drives the real :class:`~repro.core.TaskRepository` with real
OS threads on the real clock, the way the ``inproc://`` farm runs it.

Two workloads, swept over a service-count axis with tasks scaled
accordingly (``stragglers = per_service x services``):

**storm** (the gated one) — the straggler-rescue regime from the EP
literature the sharding work targets (arXiv:1305.3123 shows EP
efficiency collapsing exactly when the task source serializes): half the
farm's services have gone dead-slow, each sitting on leased tasks; the
other half polls the repository for speculative re-execution.  Every
idle poll runs the speculation scan — ``sorted(leases)`` — and on the
single-lock repository that is an O(L log L) walk of the *whole* lease
table under *the* lock, serializing every leaser and completer in the
farm.  Sharded, each scan sorts one shard's L/N slice under that shard's
lock and usually stops at the polling service's home shard.  The
measured figure is rescue dispatch throughput (stragglers re-executed
per second) and the repository's own lock-wait/lock-hold meters.
``speculation_factor=0`` makes every aged lease an immediate candidate,
isolating scan + dispatch cost from the aging policy.

**bulk** (informational) — N threads draining a pre-filled repository
(lease -> complete, no speculation): the uncontended-ish hot path, where
sharding is roughly neutral on a small host and must never regress badly.

The gate (written into ``BENCH_contention.json``):

- at the TOP service count, the best sharded configuration's storm
  throughput is >= ``--gate-min-speedup`` (default 2.0) x the
  single-lock baseline;
- ``shards=1`` is byte-identical to the pre-sharding engine on the
  same-seed ``sim://`` lease trace (the pinned golden hash below).

Caveat, stated once and honestly: on a GIL'd interpreter a sharded
repository cannot parallelize the lock-held *work* — what it removes is
the serialized O(whole-table) scans and the single-lock convoy
(wake-ups, futile scans, handoff syscalls).  That is exactly what the
storm measures, and the win grows with farm size: the single lock
collapses superlinearly as the lease table grows while the sharded
curve stays flat.  Run on a many-core host, the same harness also
exposes true lock parallelism; the gate does not depend on it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import threading
import time
import zlib

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Program, TaskRepository  # noqa: E402

SHARD_COUNTS = (1, 8, 32)

# SHA-256 over the golden sim:// scenario's lease trace, captured on the
# pre-sharding single-lock engine (PR 6).  shards=1 must reproduce it
# byte-for-byte: the facade's degenerate case IS the old repository.
GOLDEN_SHA256 = (
    "272110425a85dabb62c84e5cd537dc298bee27c8993df7037af92d535ab4685e")
GOLDEN_EVENTS = 808


# --------------------------------------------------------------------- #
# golden sim:// trace (the shards=1 identity gate)
# --------------------------------------------------------------------- #
def golden_run(seed: int = 17, n_services: int = 24, n_tasks: int = 800,
               **job_knobs):
    """One churny streaming job on the sim backend (deaths + a late
    join, batched leases, speculation on); returns (results, trace hash,
    event count).  Runs the REAL engine under the virtual clock — any
    change to lock scopes, wait sequences, or lease timestamps shows up
    in the hash."""
    from repro.sim import FaultSpec, SimCluster

    prog = Program(lambda x: x * 3.0 + 1.0, name="affine", jit=False)
    faults = {0: FaultSpec(die_at=0.2), 1: FaultSpec(die_at=0.25),
              n_services - 1: FaultSpec(register_at=0.15)}
    with SimCluster(speed_factors=[1.0] * n_services, seed=seed,
                    base_cost_s=0.5 * n_services / n_tasks, latency_s=0.0,
                    faults=faults, stall_timeout_s=300.0) as cluster:
        sched = cluster.make_scheduler(
            max_batch=4, max_inflight=1, adaptive_batching=False,
            speculation=True)
        with sched:
            job = sched.submit(prog, None, collect_results=True, **job_knobs)
            job.submit_stream((float(i) for i in range(n_tasks)),
                              window=256)
            got = {}
            for tid, result in job.as_completed():
                got[tid] = result
            job.wait(timeout=300)
            cluster.clock.sleep(3.0)
            trace = tuple(cluster.trace)
    h = hashlib.sha256()
    for item in trace:
        h.update(repr(item).encode())
    return got, h.hexdigest(), len(trace)


def check_trace_identity() -> dict:
    got, digest, n = golden_run()
    assert len(got) == 800, f"golden run lost tasks: {len(got)}/800"
    return {
        "scenario": "sim seed=17 24 services 800 tasks, 2 deaths + late "
                    "join, max_batch=4, speculation on",
        "shards": 1,
        "golden_sha256": GOLDEN_SHA256,
        "observed_sha256": digest,
        "events": n,
        "identical": digest == GOLDEN_SHA256 and n == GOLDEN_EVENTS,
    }


# --------------------------------------------------------------------- #
# real-thread workloads
# --------------------------------------------------------------------- #
def _shard_sids(shards: int, prefix: str) -> dict[int, str]:
    """One service id homing on each shard (mirrors the facade's stable
    crc32 home hash)."""
    out: dict[int, str] = {}
    j = 0
    while len(out) < shards:
        sid = f"{prefix}{j}"
        out.setdefault(zlib.crc32(sid.encode()) % shards, sid)
        j += 1
    return out


def run_storm(n_services: int, per_service: int, shards: int,
              warmup: int = 128) -> dict:
    """``n_services`` dead-slow services each leasing ``per_service``
    tasks; ``n_services`` fast services rescue them all via speculative
    re-execution.  Returns throughput + the repository's lock meters."""
    n_stragglers = n_services * per_service
    repo = TaskRepository(list(range(warmup + n_stragglers)),
                          lease_s=600.0, speculation_factor=0.0,
                          shards=shards)
    # per-shard completion history: the age arm of the speculation policy
    # needs >= 3 observed durations on a shard before it fires there (a
    # live farm accumulates these everywhere within seconds of starting)
    warm = _shard_sids(shards, "warm")
    for k in range(shards):
        for _ in range(max(warmup // shards, 3)):
            tid, payload = repo.get_task(warm[k])
            repo.complete(tid, payload, warm[k])
    for i in range(n_stragglers):  # the slow half of the farm leases...
        assert repo.get_task(f"slow{i % n_services}",
                             allow_speculation=False) is not None
    time.sleep(0.01)  # ...and goes quiet; their leases age

    t0 = time.perf_counter()

    def rescuer(sid: str) -> None:
        while True:
            got = repo.get_task(sid, timeout=0.2)
            if got is None:
                if repo.all_done:
                    return
                continue
            repo.complete(got[0], None, sid)

    threads = [threading.Thread(target=rescuer, args=(f"fast{i}",))
               for i in range(n_services)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    st = repo.stats()
    assert st["done"] == len(repo), st
    assert st["speculative_issues"] >= n_stragglers, st
    return {"workload": "storm", "services": n_services,
            "stragglers": n_stragglers, "shards": shards,
            "wall_s": round(dt, 4),
            "rescues_per_s": round(n_stragglers / dt, 1),
            "lock_wait_s": round(st["lock_wait_s"], 3),
            "lock_hold_s": round(st["lock_hold_s"], 3),
            "lock_contentions": st["lock_contentions"],
            "lock_acquisitions": st["lock_acquisitions"],
            "speculative_issues": st["speculative_issues"]}


def run_bulk(n_services: int, per_service: int, shards: int) -> dict:
    """N real threads draining a pre-filled repository, speculation off —
    the plain lease/complete hot path."""
    n_tasks = n_services * per_service
    repo = TaskRepository(list(range(n_tasks)), lease_s=600.0,
                          shards=shards)
    t0 = time.perf_counter()

    def worker(sid: str) -> None:
        while True:
            got = repo.get_task(sid, timeout=0.2, allow_speculation=False)
            if got is None:
                if repo.all_done:
                    return
                continue
            repo.complete(got[0], None, sid)

    threads = [threading.Thread(target=worker, args=(f"s{i}",))
               for i in range(n_services)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    st = repo.stats()
    assert st["done"] == n_tasks, st
    return {"workload": "bulk", "services": n_services, "tasks": n_tasks,
            "shards": shards, "wall_s": round(dt, 4),
            "tasks_per_s": round(n_tasks / dt, 1),
            "lock_wait_s": round(st["lock_wait_s"], 3),
            "lock_hold_s": round(st["lock_hold_s"], 3),
            "lock_contentions": st["lock_contentions"],
            "lock_acquisitions": st["lock_acquisitions"]}


def _best(rows: list[dict], key: str) -> dict:
    return max(rows, key=lambda r: r[key])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--services", default="16,32,64,96",
                    help="comma-separated service counts (per role: the "
                         "storm runs N slow + N fast)")
    ap.add_argument("--per-service", type=int, default=128,
                    help="straggler tasks held per slow service")
    ap.add_argument("--bulk-per-service", type=int, default=400)
    ap.add_argument("--repeats", type=int, default=2,
                    help="runs per configuration; best throughput kept "
                         "(load spikes inflate means, never maxima)")
    ap.add_argument("--gate-min-speedup", type=float, default=2.0)
    ap.add_argument("--skip-trace-identity", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)
    service_counts = [int(s) for s in args.services.split(",")]
    top = max(service_counts)

    storm_rows: list[dict] = []
    bulk_rows: list[dict] = []
    for n in service_counts:
        for shards in SHARD_COUNTS:
            reps = [run_storm(n, args.per_service, shards)
                    for _ in range(args.repeats)]
            row = _best(reps, "rescues_per_s")
            storm_rows.append(row)
            print(f"storm  services={n:3d} shards={shards:2d} "
                  f"rescues/s={row['rescues_per_s']:9.1f} "
                  f"lock_wait={row['lock_wait_s']:8.2f}s "
                  f"contentions={row['lock_contentions']}")
        for shards in SHARD_COUNTS:
            reps = [run_bulk(n, args.bulk_per_service, shards)
                    for _ in range(args.repeats)]
            row = _best(reps, "tasks_per_s")
            bulk_rows.append(row)
            print(f"bulk   services={n:3d} shards={shards:2d} "
                  f"tasks/s={row['tasks_per_s']:11.1f} "
                  f"lock_wait={row['lock_wait_s']:8.2f}s "
                  f"contentions={row['lock_contentions']}")

    at_top = [r for r in storm_rows if r["services"] == top]
    single = next(r for r in at_top if r["shards"] == 1)
    sharded = _best([r for r in at_top if r["shards"] > 1],
                    "rescues_per_s")
    speedup = sharded["rescues_per_s"] / single["rescues_per_s"]
    gate = {"workload": "storm", "top_services": top,
            "single_lock_rescues_per_s": single["rescues_per_s"],
            "best_sharded_rescues_per_s": sharded["rescues_per_s"],
            "best_sharded_shards": sharded["shards"],
            "speedup": round(speedup, 2),
            "min_speedup": args.gate_min_speedup,
            "single_lock_wait_s": single["lock_wait_s"],
            "best_sharded_lock_wait_s": sharded["lock_wait_s"],
            "passed": speedup >= args.gate_min_speedup}
    print(f"gate   storm@{top}: {single['rescues_per_s']:.0f} -> "
          f"{sharded['rescues_per_s']:.0f} rescues/s "
          f"({speedup:.1f}x, shards={sharded['shards']}) "
          f"{'PASS' if gate['passed'] else 'FAIL'}")

    identity = None
    if not args.skip_trace_identity:
        identity = check_trace_identity()
        print(f"trace  shards=1 {identity['observed_sha256'][:16]}... "
              f"({identity['events']} events) "
              f"{'IDENTICAL' if identity['identical'] else 'DIVERGED'}")

    payload = {
        "benchmark": "contention",
        "host": {"cpus": os.cpu_count(),
                 "python": sys.version.split()[0]},
        "config": {"service_counts": service_counts,
                   "per_service": args.per_service,
                   "bulk_per_service": args.bulk_per_service,
                   "repeats": args.repeats,
                   "shard_counts": list(SHARD_COUNTS)},
        "storm": storm_rows,
        "bulk": bulk_rows,
        "gate": gate,
        "trace_identity": identity,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")

    assert gate["passed"], (
        f"sharded storm throughput {speedup:.2f}x < "
        f"{args.gate_min_speedup}x single-lock at {top} services")
    if identity is not None:
        assert identity["identical"], (
            "shards=1 sim lease trace diverged from the pre-sharding "
            f"golden hash: {identity['observed_sha256']}")


def bench():
    """run.py table entry: one small storm point (32 services)."""
    single = run_storm(32, 64, 1)
    sharded = run_storm(32, 64, 8)
    us = 1e6 / single["rescues_per_s"]
    yield ("contention/storm32_shards1", us,
           f"rescues_per_s={single['rescues_per_s']:.0f}")
    us8 = 1e6 / sharded["rescues_per_s"]
    yield ("contention/storm32_shards8", us8,
           f"rescues_per_s={sharded['rescues_per_s']:.0f} "
           f"speedup={single['wall_s'] / sharded['wall_s']:.2f}x")


if __name__ == "__main__":
    main()
