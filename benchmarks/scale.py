"""NoW-scale stress: 1,000 sim services, 1M-task streams, churn bursts.

The paper's claim is that a trivially simple task farm scales across
whatever commodity nodes show up; the survey it leans on (arXiv
cs/0612105) singles out *coordination overhead* as what actually caps
task-farm throughput once pools grow.  This benchmark drives the real
farm stack over the deterministic ``sim://`` backend at Network-of-
Workstations scale and gates the scheduler's own data structures:

- **overhead curve** — the same task stream over 4 services and over N
  (default 1,000): wall-clock scheduler seconds per dispatched task must
  stay within ``OVERHEAD_RATIO_CEILING`` of the 4-service figure (it was
  superlinear before the incremental arbiter / heap clock / counter
  stats), and the arbiter must actually recompute only O(jobs) times;
- **trace determinism at scale** — the same seed must reproduce the
  byte-identical lease + scheduler event trace, and the incremental
  arbiter must produce the byte-identical traces to the legacy
  full-recompute path (``incremental_arbiter=False``) on the same seed;
- **churn** — seeded loud deaths, silent deaths and late joins
  (``FaultSpec`` schedules) over a streaming job: exactly-once results
  (count and checksum), determinism, and a bounded recompute count;
- **coalescing** — N services registering at the same virtual instant
  must cost O(1) arbiter recomputes, not N (the burst-window regression
  gate).

Memory discipline: lease traces are folded into a running SHA-256
instead of stored (a 1M-task trace list would dwarf the farm state), so
the full 1k/1M configuration runs in O(window) memory.

Rows land in ``BENCH_scale.json`` (a CI artifact via
``benchmarks/run.py --scale``, at reduced sizes: 200 services / 100k
tasks).
"""

from __future__ import annotations

import argparse
import gc
import hashlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Program  # noqa: E402
from repro.obs import Observability  # noqa: E402
from repro.sim import FaultSpec, SimCluster  # noqa: E402

PROGRAM = Program(lambda x: x * 3.0 + 1.0, name="affine", jit=False)

OVERHEAD_RATIO_CEILING = 3.0   # per-dispatch wall overhead, N vs 4 services
REBALANCE_CEILING = 16         # arbiter recomputes, steady single-job run
COALESCE_CEILING = 10          # recomputes for an N-service join burst


class _LeaseHash:
    """Recorder sink that folds every ``lease``/``speculate`` event into a
    running SHA-256 — the determinism artifact without the 1M-entry list.
    Replaces the bespoke ``on_lease`` hook (now deprecated): the recorder
    stream carries the same assignments, and the sink keeps the run in
    O(1) memory (``ring_size=0`` retains nothing)."""

    __slots__ = ("n", "_h")

    def __init__(self):
        self.n = 0
        self._h = hashlib.sha256()

    def __call__(self, ring_name, ev) -> None:
        if ev[1] not in ("lease", "speculate"):
            return
        self.n += 1
        self._h.update(repr(ev).encode())

    def digest(self) -> str:
        return self._h.hexdigest()


def _trace_hash(events) -> str:
    h = hashlib.sha256()
    for item in events:
        h.update(repr(item).encode())
    return h.hexdigest()


def run_stream(*, n_services: int, n_tasks: int, seed: int,
               incremental: bool = True, faults: dict | None = None,
               collect: bool = False, speculation: bool = False,
               max_batch: int = 8, target_makespan_s: float = 0.6,
               scenario: str = "stream") -> dict:
    """One streaming job over ``n_services`` homogeneous sim services;
    returns per-dispatch wall overhead, recompute counters, and the
    lease/scheduler trace hashes."""
    base_cost_s = target_makespan_s * n_services / n_tasks
    window = max(1024, 4 * n_services * max_batch)
    lease_hash = _LeaseHash()  # hash, don't store (1M leases)
    obs = Observability(ring_size=0, sink=lease_hash)
    t0 = time.perf_counter()
    with SimCluster(speed_factors=[1.0] * n_services, seed=seed,
                    base_cost_s=base_cost_s, latency_s=0.0,
                    faults=faults, stall_timeout_s=900.0,
                    obs=obs) as cluster:
        sched = cluster.make_scheduler(
            max_batch=max_batch, max_inflight=1, adaptive_batching=False,
            speculation=speculation, incremental_arbiter=incremental)
        with sched:
            t_submit = time.perf_counter()
            job = sched.submit(PROGRAM, None, collect_results=collect)
            job.submit_stream((float(i) for i in range(n_tasks)),
                              window=window)
            delivered = 0
            checksum = 0.0
            if collect:
                for _tid, result in job.as_completed():
                    delivered += 1
                    checksum += float(result)
            job.wait(timeout=600)
            wall_run_s = time.perf_counter() - t_submit
            stats = job.stats()
            row = {
                "scenario": scenario,
                "n_services": n_services,
                "n_tasks": n_tasks,
                "incremental_arbiter": incremental,
                "done": stats["done"],
                "delivered": delivered if collect else None,
                "checksum": checksum if collect else None,
                "virtual_makespan_s": cluster.clock.monotonic(),
                "rebalances": sched.rebalances,
                "rebalance_requests": sched.rebalance_requests,
                "revocations": sched.revocations,
                "reschedules": stats["reschedules"],
                "per_dispatch_us": wall_run_s * 1e6 / n_tasks,
                "lease_trace_hash": lease_hash.digest(),
                "lease_trace_len": lease_hash.n,
            }
            cluster.clock.sleep(5.0)  # quiesce (silent-death hangs drain)
            row["sched_trace_hash"] = _trace_hash(sched.trace)
    row["wall_s"] = time.perf_counter() - t0
    return row


def churn_faults(n_services: int, *, die_frac: float = 0.05,
                 silent_frac: float = 0.03, late_frac: float = 0.05,
                 target_makespan_s: float = 0.6) -> dict[int, FaultSpec]:
    """A deterministic churn schedule: the first ``die_frac`` of the pool
    dies loudly mid-run, the next ``silent_frac`` wedges silently, and
    the last ``late_frac`` only registers after the run is under way."""
    faults: dict[int, FaultSpec] = {}
    n_die = int(n_services * die_frac)
    n_silent = int(n_services * silent_frac)
    n_late = int(n_services * late_frac)
    for i in range(n_die):
        faults[i] = FaultSpec(die_at=0.3 * target_makespan_s)
    for i in range(n_die, n_die + n_silent):
        faults[i] = FaultSpec(die_at=0.5 * target_makespan_s, silent=True,
                              hang_s=2.0)
    for i in range(n_services - n_late, n_services):
        faults[i] = FaultSpec(register_at=0.25 * target_makespan_s)
    return faults


def run_coalescing(*, n_late: int, seed: int, n_tasks: int = 4000,
                   max_batch: int = 8) -> dict:
    """4 baseline services plus ``n_late`` registering at the same
    virtual instant mid-run: the join burst must cost O(1) recomputes."""
    t0 = time.perf_counter()
    faults = {4 + i: FaultSpec(register_at=0.3) for i in range(n_late)}
    # 4 baseline services alone would take ~1.0 virtual s, so the burst
    # at t=0.3 lands mid-run and the joiners pick up real work.
    with SimCluster(speed_factors=[1.0] * (4 + n_late), seed=seed,
                    base_cost_s=4.0 / n_tasks, latency_s=0.0,
                    faults=faults, stall_timeout_s=900.0,
                    obs=Observability(ring_size=0)) as cluster:
        sched = cluster.make_scheduler(max_batch=max_batch, max_inflight=1,
                                       adaptive_batching=False,
                                       speculation=False)
        with sched:
            job = sched.submit(PROGRAM, [float(i) for i in range(n_tasks)])
            job.wait(timeout=600)
            cluster.clock.sleep(2.0)  # let any straggling joins land
            row = {
                "scenario": "coalescing/join-burst",
                "n_late_joiners": n_late,
                "rebalances": sched.rebalances,
                "rebalance_requests": sched.rebalance_requests,
                "n_services_at_end": sched.n_services,
                "virtual_makespan_s": job.stats()["finished_at"],
            }
    row["wall_s"] = time.perf_counter() - t0
    return row


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table) — smoke sizes."""
    small = run_stream(n_services=4, n_tasks=2000, seed=7,
                       scenario="overhead/4")
    big = run_stream(n_services=64, n_tasks=2000, seed=7,
                     scenario="overhead/64")
    return [
        ("scale/per-dispatch-4svc", small["per_dispatch_us"],
         f"rebalances={small['rebalances']}"),
        ("scale/per-dispatch-64svc", big["per_dispatch_us"],
         f"ratio={big['per_dispatch_us'] / small['per_dispatch_us']:.2f}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--services", type=int, default=1000,
                    help="pool size for the big legs (CI uses 200)")
    ap.add_argument("--tasks", type=int, default=1_000_000,
                    help="stream length for the overhead legs "
                         "(CI uses 100k)")
    ap.add_argument("--churn-tasks", type=int, default=None,
                    help="stream length for the churn legs "
                         "(default tasks // 20)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--out", default=None,
                    help="write rows to this JSON file "
                         "(e.g. BENCH_scale.json)")
    args = ap.parse_args(argv)
    # the run allocates millions of short-lived tuples (trace events,
    # lease records); collector pauses add ~15% noise to the overhead
    # ratio, so measure with the GC off like the other benchmarks
    gc.disable()
    churn_tasks = (args.churn_tasks if args.churn_tasks is not None
                   else max(args.tasks // 20, 2000))
    kw = dict(seed=args.seed, max_batch=args.max_batch)
    rows = []

    # -- overhead curve: 4 services vs N, same stream ------------------ #
    small = run_stream(n_services=4, n_tasks=args.tasks,
                       scenario="overhead/4svc", **kw)
    big = run_stream(n_services=args.services, n_tasks=args.tasks,
                     scenario=f"overhead/{args.services}svc", **kw)
    ratio = big["per_dispatch_us"] / small["per_dispatch_us"]
    big["overhead_ratio_vs_4svc"] = ratio
    assert ratio <= OVERHEAD_RATIO_CEILING, (
        f"per-dispatch scheduler overhead at {args.services} services is "
        f"{ratio:.2f}x the 4-service figure (ceiling "
        f"{OVERHEAD_RATIO_CEILING}x)")
    for r in (small, big):
        assert r["done"] == args.tasks, f"{r['scenario']}: lost tasks"
        assert r["rebalances"] <= REBALANCE_CEILING, (
            f"{r['scenario']}: {r['rebalances']} arbiter recomputes for a "
            f"single steady job (ceiling {REBALANCE_CEILING})")
    rows += [small, big]

    # -- determinism + incremental==full at scale ---------------------- #
    big2 = run_stream(n_services=args.services, n_tasks=args.tasks,
                      scenario=f"overhead/{args.services}svc/rerun", **kw)
    assert big2["lease_trace_hash"] == big["lease_trace_hash"], (
        "same seed produced a different lease trace at scale")
    assert big2["sched_trace_hash"] == big["sched_trace_hash"], (
        "same seed produced a different scheduler event trace at scale")
    full = run_stream(n_services=args.services, n_tasks=args.tasks,
                      incremental=False,
                      scenario=f"overhead/{args.services}svc/full-arbiter",
                      **kw)
    assert full["lease_trace_hash"] == big["lease_trace_hash"], (
        "incremental arbiter diverged from the full recompute "
        "(lease trace)")
    assert full["sched_trace_hash"] == big["sched_trace_hash"], (
        "incremental arbiter diverged from the full recompute "
        "(scheduler trace)")
    big["trace_deterministic"] = True
    big["incremental_matches_full"] = True
    rows.append(full)

    # -- churn: deaths + late joins over a streaming job --------------- #
    faults = churn_faults(args.services)
    closed_form = 3.0 * churn_tasks * (churn_tasks - 1) / 2.0 + churn_tasks
    churn = run_stream(n_services=args.services, n_tasks=churn_tasks,
                       faults=faults, collect=True, speculation=True,
                       scenario=f"churn/{args.services}svc", **kw)
    assert churn["delivered"] == churn_tasks and \
        churn["done"] == churn_tasks, (
            f"churn lost tasks: delivered {churn['delivered']} of "
            f"{churn_tasks}")
    assert abs(churn["checksum"] - closed_form) < 1e-6 * closed_form, (
        "churn results checksum mismatch (duplicate or corrupted result)")
    churn2 = run_stream(n_services=args.services, n_tasks=churn_tasks,
                        faults=faults, collect=True, speculation=True,
                        scenario=f"churn/{args.services}svc/rerun", **kw)
    assert churn2["lease_trace_hash"] == churn["lease_trace_hash"], (
        "same seed produced a different lease trace under churn")
    churn_full = run_stream(n_services=args.services, n_tasks=churn_tasks,
                            faults=faults, collect=True, speculation=True,
                            incremental=False,
                            scenario=f"churn/{args.services}svc/full-arbiter",
                            **kw)
    assert churn_full["lease_trace_hash"] == churn["lease_trace_hash"], (
        "incremental arbiter diverged from full recompute under churn")
    churn["trace_deterministic"] = True
    churn["incremental_matches_full"] = True
    rows += [churn, churn_full]

    # -- coalescing: a same-instant join burst is one recompute -------- #
    burst = run_coalescing(n_late=min(100, args.services), seed=args.seed)
    assert burst["rebalance_requests"] >= burst["n_late_joiners"], (
        "burst did not generate per-join rebalance requests")
    assert burst["rebalances"] <= COALESCE_CEILING, (
        f"{burst['rebalances']} recomputes for a "
        f"{burst['n_late_joiners']}-service join burst (ceiling "
        f"{COALESCE_CEILING})")
    rows.append(burst)

    for r in rows:
        per = r.get("per_dispatch_us", 0.0)
        print(f"scale/{r['scenario']},{per:.2f},"
              f"rebalances={r['rebalances']} "
              f"requests={r['rebalance_requests']} "
              f"wall={r['wall_s']:.1f}s")

    if args.out:
        payload = {
            "benchmark": "scale",
            "backend": "sim",
            "seed": args.seed,
            "params": {"services": args.services, "tasks": args.tasks,
                       "churn_tasks": churn_tasks,
                       "max_batch": args.max_batch,
                       "overhead_ratio_ceiling": OVERHEAD_RATIO_CEILING,
                       "rebalance_ceiling": REBALANCE_CEILING,
                       "coalesce_ceiling": COALESCE_CEILING},
            "rows": [{k: v for k, v in r.items()
                      if not k.startswith("_")} for r in rows],
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
