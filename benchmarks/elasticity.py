"""Paper claim: services that appear mid-run are recruited automatically
(the asynchronous publish/subscribe discovery path).  Measures completion
time with 1 initial service vs 1 initial + 3 late joiners."""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp

from repro.core import BasicClient, LookupService, Program, Service

N_TASKS = 40
TASK_S = 0.01


def run(late_joiners: int) -> float:
    lookup = LookupService()
    Service(lookup, task_delay_s=TASK_S).start()

    def join():
        time.sleep(0.08)
        for _ in range(late_joiners):
            Service(lookup, task_delay_s=TASK_S).start()

    threading.Thread(target=join, daemon=True).start()
    out: list = []
    tasks = [jnp.asarray(float(i)) for i in range(N_TASKS)]
    t0 = time.perf_counter()
    BasicClient(Program(lambda x: x), None, tasks, out,
                lookup=lookup).compute(timeout=600)
    return time.perf_counter() - t0


def bench() -> list[tuple[str, float, str]]:
    solo = run(0)
    elastic = run(3)
    return [
        ("elasticity/static_1_service", solo * 1e6 / N_TASKS, ""),
        ("elasticity/plus_3_late_joiners", elastic * 1e6 / N_TASKS,
         f"speedup={solo/elastic:.2f}x (recruited mid-run)"),
    ]


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
