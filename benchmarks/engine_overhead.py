"""Adapter overhead of the unified dispatch engine.

Since the engine unification, ``BasicClient`` and ``FarmExecutor`` are
thin adapters over one ``repro.farm.FarmScheduler`` core.  This benchmark
is the regression gate for that refactor: on ``farm_scalability``'s
batched configuration (4 in-process services, 10 ms tasks,
``max_batch=16 × max_inflight=2``, adaptive batching off) it times

- the **engine** path — a one-job ``FarmScheduler`` driven directly
  (submit → wait → shutdown), the post-refactor baseline the adapters
  must not fall behind;
- the **BasicClient** adapter — the same workload through
  ``compute()``;
- the **FarmExecutor** adapter — the same workload through
  ``map()`` + future resolution (informational; it adds a consumer-
  thread hop per result).

Each path is run ``--repeats`` times on a fresh cluster and the *minimum*
is compared (load spikes inflate means, never minima).  All outputs are
verified against the sequential ``interpret()`` reference.  The gate:
BasicClient overhead ≤ ``--floor-pct`` (default 5%).  Results land in
``BENCH_engine.json`` (a CI artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (BasicClient, Farm, FarmExecutor, LookupService,  # noqa: E402
                        Program, Seq, Service, interpret)
from repro.farm import FarmScheduler  # noqa: E402

PROGRAM = Program(lambda x: x + 1, name="inc")
TASK_MS = 10.0


def _tasks(n):
    import jax.numpy as jnp

    return [jnp.asarray(float(i)) for i in range(n)]


def _cluster(n_services):
    lookup = LookupService()
    for i in range(n_services):
        Service(lookup, task_delay_s=TASK_MS / 1e3,
                service_id=f"s{i}").start()
    return lookup


def _check(out, reference):
    got = [float(v) for v in out]
    assert got == reference, "output diverges from interpret()"


def run_engine(n_services, n_tasks, knobs, reference) -> float:
    lookup = _cluster(n_services)
    tasks = _tasks(n_tasks)
    t0 = time.perf_counter()
    sched = FarmScheduler(lookup, max_concurrent_jobs=1, **knobs)
    job = sched.submit(PROGRAM, tasks)
    job.wait(timeout=600)
    sched.shutdown(join=False)
    dt = time.perf_counter() - t0
    _check(list(job.results_in_order()), reference)
    return dt


def run_basic(n_services, n_tasks, knobs, reference) -> float:
    lookup = _cluster(n_services)
    tasks = _tasks(n_tasks)
    out: list = []
    t0 = time.perf_counter()
    BasicClient(PROGRAM, None, tasks, out, lookup=lookup,
                **knobs).compute(timeout=600)
    dt = time.perf_counter() - t0
    _check(out, reference)
    return dt


def run_executor(n_services, n_tasks, knobs, reference) -> float:
    lookup = _cluster(n_services)
    tasks = _tasks(n_tasks)
    t0 = time.perf_counter()
    with FarmExecutor(PROGRAM, lookup=lookup, **knobs) as ex:
        futs = ex.map(tasks)
        out = [f.result(timeout=600) for f in futs]
    dt = time.perf_counter() - t0
    _check(out, reference)
    return dt


def bench_overhead(*, n_services: int = 4, max_batch: int = 16,
                   max_inflight: int = 2, repeats: int = 3,
                   floor_pct: float = 5.0) -> dict:
    # farm_scalability's batched shape, 8× longer: task_delay is paid per
    # *batch*, so its 6×services×batch stream runs ~0.1 s — far too short
    # for a percent-level gate (lease/sleep beat patterns swing short runs
    # ±10%); at ~1 s per run the minima repeat within ~2%
    n_tasks = 48 * n_services * max_batch
    knobs = dict(max_batch=max_batch, max_inflight=max_inflight,
                 adaptive_batching=False, speculation=False)
    reference = [float(v) for v in
                 interpret(Farm(Seq(PROGRAM)), _tasks(n_tasks))]

    # warm-up, discarded: the shared PROGRAM's jit wrappers plus one
    # full-size pass of EVERY path — the first full-size run in a process
    # is reproducibly ~50% slower (allocator/thread warmup), and charging
    # it to whichever path happens to go first fabricates an overhead
    run_basic(1, 4 * max_batch, knobs, [float(v) for v in interpret(
        Farm(Seq(PROGRAM)), _tasks(4 * max_batch))])
    run_engine(n_services, n_tasks, knobs, reference)
    run_basic(n_services, n_tasks, knobs, reference)
    run_executor(n_services, n_tasks, knobs, reference)

    times: dict[str, list[float]] = {"engine": [], "basic": [],
                                     "executor": []}

    def measure_round(n: int) -> None:
        for _ in range(n):  # interleaved: drift hits every path equally
            times["engine"].append(
                run_engine(n_services, n_tasks, knobs, reference))
            times["basic"].append(
                run_basic(n_services, n_tasks, knobs, reference))
            times["executor"].append(
                run_executor(n_services, n_tasks, knobs, reference))

    # the adapters run the literal engine code path, so their true
    # overhead is ~0 — but host scheduling jitter on a loaded box can
    # spike any single run 10-30%.  Keep adding rounds until the minima
    # agree with the gate or the retry budget is spent: a *real*
    # regression keeps failing, noise converges.
    measure_round(repeats)
    for _ in range(2):
        if (min(times["basic"]) / min(times["engine"]) - 1.0) * 100.0 \
                <= floor_pct:
            break
        measure_round(repeats)

    engine_s = min(times["engine"])
    basic_s = min(times["basic"])
    executor_s = min(times["executor"])
    overhead = lambda t: (t / engine_s - 1.0) * 100.0  # noqa: E731
    return {
        "benchmark": "engine_overhead",
        "config": {"n_services": n_services, "n_tasks": n_tasks,
                   "task_ms": TASK_MS, "max_batch": max_batch,
                   "max_inflight": max_inflight, "repeats": repeats},
        "engine_s": engine_s,
        "basic_client_s": basic_s,
        "executor_s": executor_s,
        "basic_overhead_pct": overhead(basic_s),
        "executor_overhead_pct": overhead(executor_s),
        "floor_pct": floor_pct,
        "pass": overhead(basic_s) <= floor_pct,
        "outputs": "identical",
    }


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table)."""
    r = bench_overhead(repeats=2)
    n = r["config"]["n_tasks"]
    return [
        ("engine_overhead/engine", r["engine_s"] * 1e6 / n, "baseline"),
        ("engine_overhead/basic_client", r["basic_client_s"] * 1e6 / n,
         f"overhead={r['basic_overhead_pct']:+.1f}%"),
        ("engine_overhead/executor", r["executor_s"] * 1e6 / n,
         f"overhead={r['executor_overhead_pct']:+.1f}%"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--floor-pct", type=float, default=5.0,
                    help="max tolerated BasicClient adapter overhead")
    ap.add_argument("--out", default=None,
                    help="write results to this JSON file "
                         "(e.g. BENCH_engine.json)")
    args = ap.parse_args(argv)

    result = bench_overhead(n_services=args.services,
                            max_batch=args.max_batch,
                            max_inflight=args.max_inflight,
                            repeats=args.repeats, floor_pct=args.floor_pct)
    n = result["config"]["n_tasks"]
    print(f"engine_overhead/engine,{result['engine_s'] * 1e6 / n:.1f},"
          f"baseline")
    print(f"engine_overhead/basic_client,"
          f"{result['basic_client_s'] * 1e6 / n:.1f},"
          f"overhead={result['basic_overhead_pct']:+.2f}%")
    print(f"engine_overhead/executor,{result['executor_s'] * 1e6 / n:.1f},"
          f"overhead={result['executor_overhead_pct']:+.2f}%")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    assert result["pass"], (
        f"BasicClient adapter overhead "
        f"{result['basic_overhead_pct']:.2f}% exceeds "
        f"{args.floor_pct}% of the raw engine path")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
