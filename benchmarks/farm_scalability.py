"""Paper's core claim: near-linear farm speedup with the number of services
(JJPF was evaluated on CoW/NoW; we measure the same curve on simulated
services with a fixed per-task compute cost).

``--batched`` runs the batched-vs-unbatched comparison instead: the same
workload on the per-task path (one 10 ms round-trip per task, paper
Algorithms 1-2) and on the batched async path (one round-trip per *batch*
of vmap-stacked tasks, ``max_batch``/``max_inflight`` knobs).  Both outputs
are checked against the sequential ``interpret()`` reference.

``--transport={inproc,proc}`` picks the farm backend for that comparison:
``inproc`` is the zero-copy in-process default, ``proc`` spawns one OS
worker process per service (``repro.launch.now.NowPool``) and pays real
serialization + socket round-trips.  Either way both dispatch paths are
verified bit-identical to ``interpret()``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import (BasicClient, Farm, LookupService, Program, Seq,
                        Service, interpret)

TASK_MS = 10.0
N_TASKS = 48

# one shared instance: its jit wrappers (and XLA's tracing cache) are
# memoized per device set, so warm-up runs actually warm the measured runs
PROGRAM = Program(lambda x: x + 1, name="inc")


def _program() -> Program:
    return PROGRAM


def _tasks(n: int = N_TASKS) -> list:
    return [jnp.asarray(float(i)) for i in range(n)]


def run(n_services: int, *, max_batch: int = 1, max_inflight: int = 1,
        adaptive: bool = True, n_tasks: int = N_TASKS,
        transport: str = "inproc") -> tuple[float, list]:
    lookup = LookupService()
    pool = None
    if transport == "proc":
        from repro.launch.now import NowPool

        pool = NowPool(n_services, lookup, task_delay_s=TASK_MS / 1e3,
                       service_prefix="s")
    else:
        for i in range(n_services):
            Service(lookup, task_delay_s=TASK_MS / 1e3,
                    service_id=f"s{i}").start()
    out: list = []
    tasks = _tasks(n_tasks)
    try:
        t0 = time.perf_counter()
        cm = BasicClient(_program(), None, tasks, out,
                         lookup=lookup, speculation=False, max_batch=max_batch,
                         max_inflight=max_inflight, adaptive_batching=adaptive)
        cm.compute(timeout=600)
        return time.perf_counter() - t0, out
    finally:
        if pool is not None:
            pool.shutdown()


def bench() -> list[tuple[str, float, str]]:
    rows = []
    t1 = None
    run(1, n_tasks=2)  # warm the shared PROGRAM's jit wrapper so the n=1
    # baseline doesn't carry the only cold compile (it would inflate the
    # speedups of every later row)
    for n in (1, 2, 4, 8):
        dt, _ = run(n)
        if t1 is None:
            t1 = dt
        speedup = t1 / dt
        rows.append((f"farm_scalability/services={n}", dt * 1e6 / N_TASKS,
                     f"speedup={speedup:.2f}x eff={speedup/n:.2f}"))
    return rows


def bench_batched(n_services: int = 4, *, max_batch: int = 16,
                  max_inflight: int = 2, transport: str = "inproc"
                  ) -> list[tuple[str, float, str]]:
    """Batched vs per-task throughput on the same cluster (simulated
    services in-process, or real worker processes with ``proc``), both
    verified against the sequential reference semantics."""
    n_tasks = 6 * n_services * max_batch  # amortize, keep runtime bounded
    reference = [float(v) for v in
                 interpret(Farm(Seq(_program())), _tasks(n_tasks))]

    if transport == "inproc":
        # warm up the jit caches once so neither mode pays first-compile
        # (the batched warm-up walks the controller's 1->2->...->max_batch
        # slow start, compiling every power-of-two bucket the measured
        # run's padded leases can hit).  proc workers are fresh processes
        # per run — both modes pay their own compiles, which is the honest
        # comparison for that backend.
        run(1, n_tasks=4)
        run(1, n_tasks=4 * max_batch, max_batch=max_batch,
            max_inflight=max_inflight)

    dt_seq, out_seq = run(n_services, n_tasks=n_tasks, transport=transport)
    dt_bat, out_bat = run(n_services, n_tasks=n_tasks, max_batch=max_batch,
                          max_inflight=max_inflight, adaptive=False,
                          transport=transport)
    for label, out in (("per-task", out_seq), ("batched", out_bat)):
        got = [float(v) for v in out]
        assert got == reference, f"{label} output diverges from interpret()"
    speedup = dt_seq / dt_bat
    return [
        (f"farm_batched/{transport}/services={n_services}/per_task",
         dt_seq * 1e6 / n_tasks, f"tput={n_tasks/dt_seq:.0f}/s"),
        (f"farm_batched/{transport}/services={n_services}"
         f"/batch={max_batch}x{max_inflight}",
         dt_bat * 1e6 / n_tasks,
         f"tput={n_tasks/dt_bat:.0f}/s speedup={speedup:.2f}x "
         f"outputs=identical"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batched", action="store_true",
                    help="batched-vs-per-task comparison (verified vs "
                         "the sequential interpret() reference)")
    ap.add_argument("--transport", choices=("inproc", "proc"), default=None,
                    help="farm backend; selecting one runs the batched-vs-"
                         "per-task comparison over it (proc = one OS "
                         "process per service)")
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-inflight", type=int, default=2)
    args = ap.parse_args()
    rows = (bench_batched(args.services, max_batch=args.max_batch,
                          max_inflight=args.max_inflight,
                          transport=args.transport or "inproc")
            if (args.batched or args.transport) else bench())
    for r in rows:
        print(",".join(str(x) for x in r))
