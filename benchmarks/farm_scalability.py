"""Paper's core claim: near-linear farm speedup with the number of services
(JJPF was evaluated on CoW/NoW; we measure the same curve on simulated
services with a fixed per-task compute cost)."""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import BasicClient, LookupService, Program, Service

TASK_MS = 10.0
N_TASKS = 48


def run(n_services: int) -> float:
    lookup = LookupService()
    for i in range(n_services):
        Service(lookup, task_delay_s=TASK_MS / 1e3,
                service_id=f"s{i}").start()
    out: list = []
    tasks = [jnp.asarray(float(i)) for i in range(N_TASKS)]
    t0 = time.perf_counter()
    cm = BasicClient(Program(lambda x: x + 1), None, tasks, out,
                     lookup=lookup, speculation=False)
    cm.compute(timeout=600)
    return time.perf_counter() - t0


def bench() -> list[tuple[str, float, str]]:
    rows = []
    t1 = None
    for n in (1, 2, 4, 8):
        dt = run(n)
        if t1 is None:
            t1 = dt
        speedup = t1 / dt
        rows.append((f"farm_scalability/services={n}", dt * 1e6 / N_TASKS,
                     f"speedup={speedup:.2f}x eff={speedup/n:.2f}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
