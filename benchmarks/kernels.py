"""Kernel-layer micro-benchmarks (CPU timings are NOT TPU performance —
they validate plumbing and give relative XLA-path costs; the TPU numbers
come from the §Roofline dry-run analysis)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_chunked, attention_naive
from repro.kernels.flash_attention.xla import flash_attention_xla


def _time(f, *args, n=5):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench() -> list[tuple[str, float, str]]:
    from repro.tune import DEFAULTS, best_config

    rows = []
    B, S, H, K, D = 1, 1024, 8, 2, 64
    # independent keys per tensor: reusing one PRNG key makes q == k up
    # to reshape, which collapses the score distribution the softmax
    # normalizes over — the timings were of an unrepresentative input
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, K, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, K, D), jnp.float32)

    # chunking comes from the tuning cache (hand-picked default when
    # untuned) — the benchmark measures what dispatch actually runs
    cfg = best_config("xla_flash",
                      {"B": B, "Sq": S, "Skv": S, "H": H, "K": K, "D": D,
                       "Dv": D}, "float32", "xla", DEFAULTS["xla_flash"])
    naive = jax.jit(lambda q, k, v: attention_naive(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention_xla(
        q, k, v, True, None, cfg["q_chunk"], cfg["kv_chunk"]))
    t_naive = _time(naive, q, k, v)
    t_flash = _time(flash, q, k, v)
    rows.append(("kernels/attention_naive_1k", t_naive * 1e6,
                 "materializes S^2 scores"))
    rows.append(("kernels/attention_flash_xla_1k", t_flash * 1e6,
                 f"rel={t_flash/t_naive:.2f}x (memory O(S)) "
                 f"chunks={cfg['q_chunk']}/{cfg['kv_chunk']}"))

    from repro.kernels.mamba_scan.ref import mamba_scan_naive, mamba_scan_ref

    b, s, d, n = 2, 512, 64, 16
    kx, kdt, ka, kb, kc = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(kx, (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(kdt, (b, s, d)))
    A = -jnp.exp(jax.random.normal(ka, (d, n)) * 0.5)
    Bm = jax.random.normal(kb, (b, s, n))
    C = jax.random.normal(kc, (b, s, n))
    mcfg = best_config("mamba", {"b": b, "s": s, "d": d, "n": n},
                       "float32", "xla", DEFAULTS["mamba"])
    seq = jax.jit(lambda *a: mamba_scan_naive(*a)[0])
    chunked = jax.jit(lambda *a: mamba_scan_ref(*a, chunk=mcfg["chunk"])[0])
    t_seq = _time(seq, x, dt, A, Bm, C)
    t_chk = _time(chunked, x, dt, A, Bm, C)
    rows.append(("kernels/mamba_seq_scan_512", t_seq * 1e6, ""))
    rows.append(("kernels/mamba_chunked_scan_512", t_chk * 1e6,
                 f"speedup={t_seq/t_chk:.2f}x (chunked assoc-scan) "
                 f"chunk={mcfg['chunk']}"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
