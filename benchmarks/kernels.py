"""Kernel-layer micro-benchmarks (CPU timings are NOT TPU performance —
they validate plumbing and give relative XLA-path costs; the TPU numbers
come from the §Roofline dry-run analysis)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_chunked, attention_naive
from repro.kernels.flash_attention.xla import flash_attention_xla


def _time(f, *args, n=5):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench() -> list[tuple[str, float, str]]:
    rows = []
    B, S, H, K, D = 1, 1024, 8, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, K, D), jnp.float32)
    v = jax.random.normal(key, (B, S, K, D), jnp.float32)

    naive = jax.jit(lambda q, k, v: attention_naive(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention_xla(q, k, v, True, None,
                                                        256, 256))
    t_naive = _time(naive, q, k, v)
    t_flash = _time(flash, q, k, v)
    rows.append(("kernels/attention_naive_1k", t_naive * 1e6,
                 "materializes S^2 scores"))
    rows.append(("kernels/attention_flash_xla_1k", t_flash * 1e6,
                 f"rel={t_flash/t_naive:.2f}x (memory O(S))"))

    from repro.kernels.mamba_scan.ref import mamba_scan_naive, mamba_scan_ref

    b, s, d, n = 2, 512, 64, 16
    x = jax.random.normal(key, (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, d)))
    A = -jnp.exp(jax.random.normal(key, (d, n)) * 0.5)
    Bm = jax.random.normal(key, (b, s, n))
    C = jax.random.normal(key, (b, s, n))
    seq = jax.jit(lambda *a: mamba_scan_naive(*a)[0])
    chunked = jax.jit(lambda *a: mamba_scan_ref(*a)[0])
    t_seq = _time(seq, x, dt, A, Bm, C)
    t_chk = _time(chunked, x, dt, A, Bm, C)
    rows.append(("kernels/mamba_seq_scan_512", t_seq * 1e6, ""))
    rows.append(("kernels/mamba_chunked_scan_512", t_chk * 1e6,
                 f"speedup={t_seq/t_chk:.2f}x (chunked assoc-scan)"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
