"""Multi-tenant farm scheduling: fairness, isolation, rebalance latency.

JJPF's pitch is that many independent applications time-share one shared
CoW/NoW pool; ``repro.farm.FarmScheduler`` makes the arbitration explicit
(weighted fair share + revocable recruitment).  This benchmark measures
it on the deterministic ``sim://`` backend:

- **fairness** — N equal-weight jobs over one pool: per-job throughput,
  each job's share of pool throughput, and Jain's fairness index;
- **weights** — a 2:1-weighted pair: the observed service-share ratio;
- **rebalance latency** — a job submitted mid-run: virtual time from
  submission until the first task it gets to run on a revoked-and-
  reassigned service.

All outputs are verified against the sequential ``interpret()``
reference, the fairness scenario is re-run under the same seed to assert
trace determinism, and the rows land in ``BENCH_multitenant.json``
(uploaded as a CI artifact).

Acceptance floors (asserted): with two equal-weight jobs each holds
>= 0.45 of total pool throughput; Jain index >= 0.95 at four jobs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Farm, Program, Seq, interpret  # noqa: E402
from repro.farm import jain_index  # noqa: E402
from repro.sim import SimCluster  # noqa: E402

PROGRAM = Program(lambda x: x * 3.0 + 1.0, name="affine", jit=False)

EQUAL_SHARE_FLOOR = 0.45  # of total pool throughput, 2 equal jobs
JAIN_FLOOR = 0.95         # 4 equal jobs


def _tasks(n: int) -> list:
    return [float(i) for i in range(n)]


def _reference(n: int) -> list:
    return [float(v) for v in interpret(Farm(Seq(PROGRAM)), _tasks(n))]


def run_fairness(n_jobs: int, *, seed: int, n_services: int, n_tasks: int,
                 base_cost_ms: float, max_batch: int) -> dict:
    """N equal-weight concurrent jobs; returns shares + Jain + traces."""
    t0 = time.perf_counter()
    with SimCluster(speed_factors=[1.0] * n_services, seed=seed,
                    base_cost_s=base_cost_ms / 1e3,
                    latency_s=0.0001, latency_jitter_s=0.00001) as cluster:
        sched = cluster.make_scheduler(max_batch=max_batch, max_inflight=2)
        with sched:
            jobs = [sched.submit(PROGRAM, _tasks(n_tasks))
                    for _ in range(n_jobs)]
            for job in jobs:
                job.wait(timeout=600)
            makespan = cluster.clock.monotonic()
            reference = _reference(n_tasks)
            shares = []
            for job in jobs:
                got = [float(v) for v in job.results_in_order()]
                assert got == reference, \
                    f"{job.job_id} diverges from interpret()"
                span = job.finished_at - job.started_at
                shares.append((n_tasks / span) / (n_jobs * n_tasks / makespan))
            cluster.clock.sleep(2.0)  # quiesce before reading traces
            trace = list(sched.trace)
            lease_trace = list(cluster.trace)
    return {
        "scenario": f"fairness/{n_jobs}jobs",
        "n_jobs": n_jobs,
        "n_services": n_services,
        "n_tasks_per_job": n_tasks,
        "virtual_makespan_s": makespan,
        "throughput_shares": shares,
        "min_share": min(shares),
        "jain_index": jain_index(shares),
        "wall_ms": (time.perf_counter() - t0) * 1e3,
        "_trace": trace,
        "_lease_trace": lease_trace,
    }


def run_weighted(*, seed: int, n_services: int, n_tasks: int,
                 base_cost_ms: float, max_batch: int) -> dict:
    """weight-2 vs weight-1 job: measured completion-rate ratio while
    both run (read at the heavy job's finish line)."""
    t0 = time.perf_counter()
    with SimCluster(speed_factors=[1.0] * n_services, seed=seed,
                    base_cost_s=base_cost_ms / 1e3,
                    latency_s=0.0001, latency_jitter_s=0.00001) as cluster:
        sched = cluster.make_scheduler(max_batch=max_batch, max_inflight=2)
        with sched:
            heavy = sched.submit(PROGRAM, _tasks(n_tasks), weight=2.0)
            light = sched.submit(PROGRAM, _tasks(n_tasks), weight=1.0)
            n_heavy_services = len(sched.services_of(heavy))
            heavy.wait(timeout=600)
            light_done = light.stats()["done"]
            light.wait(timeout=600)
            reference = _reference(n_tasks)
            for job in (heavy, light):
                got = [float(v) for v in job.results_in_order()]
                assert got == reference
            cluster.clock.sleep(2.0)
    return {
        "scenario": "weighted/2:1",
        "n_services": n_services,
        "heavy_services_at_start": n_heavy_services,
        "completion_ratio_at_heavy_end": n_tasks / max(light_done, 1),
        "wall_ms": (time.perf_counter() - t0) * 1e3,
    }


def run_rebalance_latency(*, seed: int, n_services: int, n_tasks: int,
                          base_cost_ms: float, max_batch: int) -> dict:
    """Submit a second job mid-run; latency = virtual time from its
    submission to its first lease on a (revoked, reassigned) service."""
    t0 = time.perf_counter()
    with SimCluster(speed_factors=[1.0] * n_services, seed=seed,
                    base_cost_s=base_cost_ms / 1e3,
                    latency_s=0.0001, latency_jitter_s=0.00001) as cluster:
        sched = cluster.make_scheduler(max_batch=max_batch, max_inflight=2)
        with sched:
            first = sched.submit(PROGRAM, _tasks(n_tasks))
            first.repository.wait_until(
                lambda s: s["done"] >= n_tasks // 4, timeout=600)
            late = sched.submit(PROGRAM, _tasks(n_tasks))
            t_submit = next(t for ev, t, jid, *_ in sched.trace
                            if ev == "job-submit" and jid == late.job_id)
            first.wait(timeout=600)
            late.wait(timeout=600)
            t_first_lease = next(
                t for t, key, _sid, _att in cluster.trace
                if str(key).startswith(f"{late.job_id}/"))
            n_revocations = sched.revocations
            cluster.clock.sleep(2.0)
    return {
        "scenario": "rebalance-latency/mid-run-submit",
        "n_services": n_services,
        "rebalance_latency_s": t_first_lease - t_submit,
        "revocations": n_revocations,
        "wall_ms": (time.perf_counter() - t0) * 1e3,
    }


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table)."""
    rows = []
    fair = run_fairness(2, seed=7, n_services=4, n_tasks=240,
                        base_cost_ms=1.0, max_batch=8)
    rows.append(("multi_tenant/fairness-2jobs",
                 fair["virtual_makespan_s"] * 1e6 / (2 * 240),
                 f"min_share={fair['min_share']:.3f} "
                 f"jain={fair['jain_index']:.4f}"))
    lat = run_rebalance_latency(seed=7, n_services=4, n_tasks=240,
                                base_cost_ms=1.0, max_batch=8)
    rows.append(("multi_tenant/rebalance-latency",
                 lat["rebalance_latency_s"] * 1e6,
                 f"revocations={lat['revocations']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=4,
                    help="jobs in the Jain-index fairness scenario")
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=240,
                    help="tasks per job")
    ap.add_argument("--base-cost-ms", type=float, default=1.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=None,
                    help="write rows to this JSON file "
                         "(e.g. BENCH_multitenant.json)")
    args = ap.parse_args(argv)

    kw = dict(seed=args.seed, n_services=args.services, n_tasks=args.tasks,
              base_cost_ms=args.base_cost_ms, max_batch=args.max_batch)
    rows = []

    # two equal jobs: the headline fairness floor + determinism gate
    pair = run_fairness(2, **kw)
    rerun = run_fairness(2, **kw)
    assert pair["_trace"] == rerun["_trace"], (
        "same seed produced a different scheduler event trace")
    assert pair["_lease_trace"] == rerun["_lease_trace"], (
        "same seed produced a different cross-job lease trace")
    assert pair["min_share"] >= EQUAL_SHARE_FLOOR, (
        f"min equal-weight share {pair['min_share']:.3f} below "
        f"{EQUAL_SHARE_FLOOR}")
    pair["trace_deterministic"] = True
    rows.append(pair)

    # N equal jobs: Jain index
    many = run_fairness(args.jobs, **kw)
    assert many["jain_index"] >= JAIN_FLOOR, (
        f"Jain index {many['jain_index']:.4f} below {JAIN_FLOOR}")
    rows.append(many)

    # 2:1 weights — over 6 services, where the 4:2 quota is exact (with
    # a non-integer quota the remainder service parks on one job between
    # events; see docs/architecture.md)
    # (fine-grained leases: the ratio is read at one instant, and 8-task
    # lease granularity would blur it)
    weighted = run_weighted(**{**kw, "n_services": max(args.services, 6),
                               "max_batch": 2})
    rows.append(weighted)

    # rebalance latency
    latency = run_rebalance_latency(**kw)
    rows.append(latency)

    for row in rows:
        name = row["scenario"]
        if "jain_index" in row:
            print(f"multi_tenant/{name},"
                  f"{row['virtual_makespan_s'] * 1e3:.2f},"
                  f"min_share={row['min_share']:.3f} "
                  f"jain={row['jain_index']:.4f} "
                  f"wall={row['wall_ms']:.0f}ms")
        elif "rebalance_latency_s" in row:
            print(f"multi_tenant/{name},"
                  f"{row['rebalance_latency_s'] * 1e6:.1f},"
                  f"revocations={row['revocations']} "
                  f"wall={row['wall_ms']:.0f}ms")
        else:
            print(f"multi_tenant/{name},"
                  f"{row['completion_ratio_at_heavy_end']:.2f},"
                  f"heavy_services={row['heavy_services_at_start']} "
                  f"wall={row['wall_ms']:.0f}ms")

    if args.out:
        payload = {
            "benchmark": "multi_tenant",
            "backend": "sim",
            "seed": args.seed,
            "params": {"jobs": args.jobs, "services": args.services,
                       "tasks_per_job": args.tasks,
                       "base_cost_ms": args.base_cost_ms,
                       "max_batch": args.max_batch},
            "rows": [{k: v for k, v in r.items()
                      if not k.startswith("_")} for r in rows],
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
