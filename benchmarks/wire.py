"""Wire microbenchmark: what each transport pays per dispatched task.

One identity task (``jit=False`` — no compute, pure dispatch), one array
payload, four backends:

- **inproc** — the zero-copy live-object baseline;
- **shm**    — proc's socket protocol, array leaves over a shared-memory
  ring (descriptors on the socket);
- **proc**   — the full serialize → socket → deserialize round-trip;
- **tcp**    — proc's data plane behind the network LookupServer (same
  wire cost, plus whatever the discovery plane adds at setup).

Two currencies are reported per backend: **µs/task** (min over repeated
runs; spikes inflate means, never minima) and **payload bytes that
crossed the socket per task** (both directions; for shm the ring bytes
are reported separately — they are memcpys, not socket copies).

The acceptance gates (``pass`` in ``BENCH_wire.json``):

- shm moves strictly fewer payload bytes over the socket than proc;
- proc's µs/task is ≥ ``--speedup-floor`` (default 2×) shm's on array
  payloads.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import Program, Service, resolve_handle  # noqa: E402

#: dispatch only — the benchmark measures the transport, not the task
PROGRAM = Program(lambda x: x, jit=False, name="ident")


def _payload(n_floats: int) -> np.ndarray:
    return np.arange(n_floats, dtype=np.float32)


def _time_executes(handle, payload: np.ndarray, n_tasks: int,
                   repeats: int) -> tuple[float, dict]:
    """min µs/task over ``repeats`` runs + per-task byte counters."""
    handle.prepare(PROGRAM)
    out = handle.execute(PROGRAM, payload)  # warm-up + correctness
    np.testing.assert_array_equal(np.asarray(out), payload)

    best_s = float("inf")
    b_out0 = getattr(handle, "payload_bytes_out", 0)
    b_in0 = getattr(handle, "payload_bytes_in", 0)
    ring0 = getattr(handle, "shm_bytes_out", 0)
    done = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n_tasks):
            handle.execute(PROGRAM, payload)
        best_s = min(best_s, time.perf_counter() - t0)
        done += n_tasks
    counters = {
        "socket_payload_bytes_per_task":
            (getattr(handle, "payload_bytes_out", 0) - b_out0
             + getattr(handle, "payload_bytes_in", 0) - b_in0) / done,
        "ring_bytes_per_task":
            (getattr(handle, "shm_bytes_out", 0) - ring0) / done,
    }
    return best_s / n_tasks * 1e6, counters


def bench_inproc(payload, n_tasks, repeats):
    svc = Service(None, service_id="wire-inproc")
    handle = resolve_handle(svc.descriptor())
    us, counters = _time_executes(handle, payload, n_tasks, repeats)
    return us, counters


def bench_now(payload, n_tasks, repeats, transport):
    from repro.launch.now import NowPool

    with NowPool(1, service_prefix=f"wire-{transport}",
                 transport=transport) as pool:
        handle = resolve_handle(pool.workers[0].descriptor)
        try:
            return _time_executes(handle, payload, n_tasks, repeats)
        finally:
            handle.close()


def bench_tcp(payload, n_tasks, repeats):
    from repro.launch.tcp import TcpPool

    with TcpPool(1, service_prefix="wire-tcp") as pool:
        (desc,) = pool.lookup.query()
        handle = resolve_handle(desc)
        try:
            return _time_executes(handle, payload, n_tasks, repeats)
        finally:
            handle.close()


def bench_wire(*, n_tasks: int = 200, payload_floats: int = 262144,
               repeats: int = 3, speedup_floor: float = 2.0) -> dict:
    payload = _payload(payload_floats)
    backends: dict[str, dict] = {}
    for name, runner in (
            ("inproc", lambda: bench_inproc(payload, n_tasks, repeats)),
            ("shm", lambda: bench_now(payload, n_tasks, repeats, "shm")),
            ("proc", lambda: bench_now(payload, n_tasks, repeats, "proc")),
            ("tcp", lambda: bench_tcp(payload, n_tasks, repeats))):
        us, counters = runner()
        backends[name] = {"us_per_task": us, **counters}

    shm_bytes = backends["shm"]["socket_payload_bytes_per_task"]
    proc_bytes = backends["proc"]["socket_payload_bytes_per_task"]
    speedup = backends["proc"]["us_per_task"] / backends["shm"]["us_per_task"]
    gates = {
        "shm_socket_bytes_lt_proc": shm_bytes < proc_bytes,
        "proc_over_shm_speedup": speedup,
        "speedup_floor": speedup_floor,
        "speedup_ok": speedup >= speedup_floor,
    }
    return {
        "benchmark": "wire",
        "config": {"n_tasks": n_tasks, "payload_floats": payload_floats,
                   "payload_bytes": int(payload.nbytes),
                   "repeats": repeats},
        "backends": backends,
        "gates": gates,
        "pass": gates["shm_socket_bytes_lt_proc"] and gates["speedup_ok"],
    }


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table)."""
    r = bench_wire(n_tasks=60, repeats=2)
    rows = []
    for name, b in r["backends"].items():
        rows.append((f"wire/{name}", b["us_per_task"],
                     f"socket_B/task={b['socket_payload_bytes_per_task']:.0f}"))
    rows.append(("wire/proc_over_shm", r["gates"]["proc_over_shm_speedup"],
                 f"pass={r['pass']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=200)
    ap.add_argument("--payload-floats", type=int, default=262144,
                    help="float32 elements per payload (default 1 MiB)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--speedup-floor", type=float, default=2.0,
                    help="minimum proc/shm µs-per-task ratio")
    ap.add_argument("--out", default=None,
                    help="write results to this JSON file "
                         "(e.g. BENCH_wire.json)")
    args = ap.parse_args(argv)

    result = bench_wire(n_tasks=args.tasks,
                        payload_floats=args.payload_floats,
                        repeats=args.repeats,
                        speedup_floor=args.speedup_floor)
    for name, b in result["backends"].items():
        print(f"wire/{name},{b['us_per_task']:.1f},"
              f"socket_B/task={b['socket_payload_bytes_per_task']:.0f} "
              f"ring_B/task={b['ring_bytes_per_task']:.0f}")
    g = result["gates"]
    print(f"wire/proc_over_shm,{g['proc_over_shm_speedup']:.2f},"
          f"floor={g['speedup_floor']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    assert result["pass"], (
        f"wire gate failed: shm socket bytes "
        f"{result['backends']['shm']['socket_payload_bytes_per_task']:.0f} "
        f"vs proc "
        f"{result['backends']['proc']['socket_payload_bytes_per_task']:.0f}; "
        f"proc/shm speedup {g['proc_over_shm_speedup']:.2f}x "
        f"(floor {g['speedup_floor']}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
