"""The paper's heterogeneous-NoW claim, reproduced in milliseconds.

JJPF §3 (Figs. 2–4) reports near-ideal efficiency on Networks of
Workstations whose nodes differ in speed, because pull scheduling
load-balances automatically.  This benchmark reruns that experiment on
the deterministic ``sim://`` backend: for a speed mix like ``1,1,2,4``
(1.0 = baseline, 4.0 = four times slower) it sweeps the parallelism
degree — farms over the first n services of the mix — and reports
**efficiency vs. the ideal latency-free makespan**
(``total_work / aggregate service rate``) at each degree.  Ninety virtual
seconds of cluster time cost milliseconds of wall time, and the same seed
reproduces the identical task-to-service assignment trace, which this
benchmark also verifies by running the full mix twice.

Outputs are checked against the sequential ``interpret()`` reference, and
the rows land in ``BENCH_heterogeneous.json`` (uploaded as a CI artifact)
so the efficiency trajectory is tracked over time.

Acceptance floors (asserted): the uniform mix holds efficiency ≥ 0.9 of
ideal at full degree, heterogeneous mixes ≥ 0.8.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Farm, Program, Seq, interpret  # noqa: E402
from repro.obs import Observability  # noqa: E402
from repro.obs.export import (export_chrome_trace,  # noqa: E402
                              validate_chrome_trace)
from repro.sim import SimCluster  # noqa: E402

# one shared program: its jit wrappers (and XLA's tracing cache) are
# memoized per device set, so later rows don't re-pay compiles
PROGRAM = Program(lambda x: x * 3.0 + 1.0, name="affine")

UNIFORM_FLOOR = 0.90
HETERO_FLOOR = 0.80


def _tasks(n: int) -> list:
    import jax.numpy as jnp

    return [jnp.asarray(float(i)) for i in range(n)]


def run_mix(mix: list[float], *, seed: int, n_tasks: int,
            base_cost_ms: float, latency_ms: float, max_batch: int,
            degree: int | None = None) -> dict:
    """One farm over the first ``degree`` services of ``mix``; returns the
    measured row (virtual makespan, efficiency, wall time, trace)."""
    speeds = mix[: degree or len(mix)]
    tasks = _tasks(n_tasks)
    reference = [float(v) for v in interpret(Farm(Seq(PROGRAM)), tasks)]
    # the recorder IS the assignment trace now (the bespoke on_lease hook
    # is deprecated): lease events carry (service_id, ((tid, attempt),…))
    obs = Observability()
    t0 = time.perf_counter()
    with SimCluster(speed_factors=speeds, seed=seed,
                    base_cost_s=base_cost_ms / 1e3,
                    latency_s=latency_ms / 1e3,
                    latency_jitter_s=latency_ms / 1e4,
                    obs=obs) as cluster:
        out, client = cluster.run(PROGRAM, tasks, max_batch=max_batch,
                                  max_inflight=2, lease_s=5.0)
        makespan = cluster.clock.monotonic()
        trace = obs.events()
        stats = client.stats()
        ideal = cluster.ideal_makespan(n_tasks)
    wall_ms = (time.perf_counter() - t0) * 1e3
    got = [float(v) for v in out]
    assert got == reference, "sim farm output diverges from interpret()"
    return {
        "mix": speeds,
        "degree": len(speeds),
        "n_tasks": n_tasks,
        "virtual_makespan_s": makespan,
        "ideal_makespan_s": ideal,
        "efficiency": ideal / makespan,
        "wall_ms": wall_ms,
        "per_service": stats["per_service"],
        "trace_len": len(trace),
        "_trace": trace,  # stripped before JSON; used for determinism check
        "_obs": obs,      # stripped before JSON; used for --trace export
    }


def efficiency_curve(mix: list[float], *, seed: int, n_tasks: int,
                     base_cost_ms: float, latency_ms: float,
                     max_batch: int) -> list[dict]:
    rows = []
    for degree in range(1, len(mix) + 1):
        row = run_mix(mix, seed=seed, n_tasks=n_tasks,
                      base_cost_ms=base_cost_ms, latency_ms=latency_ms,
                      max_batch=max_batch, degree=degree)
        rows.append(row)
    return rows


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table): full-degree uniform
    and heterogeneous mixes, µs of *virtual* time per task."""
    rows = []
    for mix in ([1.0, 1.0, 1.0, 1.0], [1.0, 1.0, 2.0, 4.0]):
        r = run_mix(mix, seed=7, n_tasks=240, base_cost_ms=1.0,
                    latency_ms=0.1, max_batch=8)
        rows.append((
            f"heterogeneous_now/mix={','.join(str(s) for s in r['mix'])}",
            r["virtual_makespan_s"] * 1e6 / r["n_tasks"],
            f"eff={r['efficiency']:.3f} virtual wall={r['wall_ms']:.0f}ms"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", default=None,
                    help="comma-separated speed factors, e.g. 1,1,2,4 "
                         "(default: run the uniform AND the paper-style "
                         "heterogeneous mix)")
    ap.add_argument("--tasks", type=int, default=240)
    ap.add_argument("--base-cost-ms", type=float, default=1.0)
    ap.add_argument("--latency-ms", type=float, default=0.1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=None,
                    help="write rows to this JSON file "
                         "(e.g. BENCH_heterogeneous.json)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the full-degree run of the last mix as "
                         "Chrome trace-event JSON (load in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args(argv)

    mixes = ([[float(s) for s in args.mix.split(",")]] if args.mix
             else [[1.0, 1.0, 1.0, 1.0], [1.0, 1.0, 2.0, 4.0]])
    kw = dict(seed=args.seed, n_tasks=args.tasks,
              base_cost_ms=args.base_cost_ms, latency_ms=args.latency_ms,
              max_batch=args.max_batch)

    all_rows = []
    last_full = None
    for mix in mixes:
        rows = efficiency_curve(mix, **kw)
        # determinism gate: the full-degree run, repeated with the same
        # seed, must produce the identical recorder event trace
        rerun = run_mix(mix, **kw)
        assert rerun["_trace"] == rows[-1]["_trace"], (
            "same seed produced a different task-to-service trace")
        last_full = rows[-1]
        uniform = len(set(mix)) == 1
        floor = UNIFORM_FLOOR if uniform else HETERO_FLOOR
        full = rows[-1]
        assert full["efficiency"] >= floor, (
            f"mix {mix}: efficiency {full['efficiency']:.3f} below the "
            f"{floor:.0%} floor")
        for row in rows:
            print(f"heterogeneous_now/mix={','.join(str(s) for s in row['mix'])}"
                  f"/degree={row['degree']},"
                  f"{row['virtual_makespan_s'] * 1e6 / row['n_tasks']:.2f},"
                  f"eff={row['efficiency']:.3f} "
                  f"wall={row['wall_ms']:.0f}ms "
                  f"trace=deterministic")
        all_rows.extend(rows)

    if args.trace and last_full is not None:
        export_chrome_trace(last_full["_obs"], args.trace)
        info = validate_chrome_trace(args.trace)
        print(f"wrote {args.trace} ({info['events']} trace events, "
              f"{info['service_tracks']} service tracks, "
              f"{len(info['event_types'])} event types)")

    if args.out:
        payload = {
            "benchmark": "heterogeneous_now",
            "backend": "sim",
            "seed": args.seed,
            "params": {"tasks": args.tasks,
                       "base_cost_ms": args.base_cost_ms,
                       "latency_ms": args.latency_ms,
                       "max_batch": args.max_batch},
            "rows": [{k: v for k, v in r.items()
                      if k not in ("_trace", "_obs")}
                     for r in all_rows],
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
