"""Benchmark harness: one module per paper claim/table.

Prints ``name,us_per_call,derived`` CSV.  The roofline table (per-arch
TPU-target analysis) is produced separately by ``repro.launch.roofline``
from the dry-run artifacts and summarized here if present.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def compare_batched(args) -> None:
    """Batched-vs-unbatched dispatch comparison (verified against the
    sequential ``interpret()`` reference)."""
    from benchmarks import farm_scalability

    print("name,us_per_call,derived")
    for name, us, derived in farm_scalability.bench_batched(
            args.services, max_batch=args.max_batch,
            max_inflight=args.max_inflight, transport=args.transport):
        print(f"{name},{us:.1f},{derived}")


def run_engine_overhead(args) -> None:
    """The engine-unification gate: the single-tenant adapters vs the
    raw one-job FarmScheduler path; writes ``BENCH_engine.json`` and
    fails if BasicClient's overhead exceeds the floor."""
    from benchmarks import engine_overhead as mod

    mod.main(["--out", args.engine_out])


def run_scale(args) -> None:
    """The NoW-scale scheduler gate: per-dispatch overhead curve, trace
    determinism, incremental-vs-full arbiter equivalence, churn and
    join-burst coalescing; writes ``BENCH_scale.json``.  CI runs a
    reduced configuration (200 services / 100k tasks); the full 1,000 /
    1M figures are produced locally with ``benchmarks/scale.py``."""
    from benchmarks import scale as mod

    mod.main(["--services", str(args.scale_services),
              "--tasks", str(args.scale_tasks),
              "--out", args.scale_out])


def run_contention(args) -> None:
    """The sharded-repository gate: real-thread lock contention for 1 vs
    8 vs 32 shards (straggler-storm rescue throughput + lock-wait
    meters) and the shards=1 golden-trace identity check; writes
    ``BENCH_contention.json``.  CI runs a reduced sweep; the full curve
    is produced locally with ``benchmarks/contention.py``."""
    from benchmarks import contention as mod

    mod.main(["--services", args.contention_services,
              "--per-service", str(args.contention_per_service),
              "--repeats", str(args.contention_repeats),
              "--out", args.contention_out])


def run_obs(args) -> None:
    """The telemetry-spine gate: recorder overhead vs tracing-disabled
    on the batched inproc path (≤3%), plus the heterogeneous-NoW Chrome
    trace artifact; writes ``BENCH_obs.json`` and the trace JSON.  CI
    runs a reduced configuration; the committed figures come from the
    module's defaults (``benchmarks/observability.py``)."""
    from benchmarks import observability as mod

    mod.main(["--tasks", str(args.obs_tasks),
              "--repeats", str(args.obs_repeats),
              "--out", args.obs_out,
              "--trace-out", args.obs_trace_out])


def run_wire(args) -> None:
    """The transport gate: µs/task and socket payload bytes for inproc vs
    shm vs proc vs tcp on array payloads; writes ``BENCH_wire.json`` and
    fails unless shm beats proc on both bytes and the speedup floor.  CI
    runs a reduced configuration; the committed figures come from the
    module's defaults (``benchmarks/wire.py``)."""
    from benchmarks import wire as mod

    mod.main(["--tasks", str(args.wire_tasks),
              "--repeats", str(args.wire_repeats),
              "--out", args.wire_out])


def run_autotune(args) -> None:
    """The autotuning gate: real farm sweep + serial re-time (tuned must
    beat the hand-picked default by the speedup floor), same-seed sim://
    determinism, and cache-hit dispatch overhead ≤3% of kernel time;
    writes ``BENCH_autotune.json``.  CI runs a reduced sweep (mamba
    only); the committed figures come from the module's defaults
    (``benchmarks/autotune.py``)."""
    from benchmarks import autotune as mod

    mod.main(["--kernels", args.autotune_kernels,
              "--reps", str(args.autotune_reps),
              "--out", args.autotune_out])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare-batched", action="store_true",
                    help="only run the batched-vs-per-task dispatch "
                         "comparison (farm_scalability --batched)")
    ap.add_argument("--engine-overhead", action="store_true",
                    help="only run the unified-engine adapter-overhead "
                         "gate (BasicClient/FarmExecutor vs raw "
                         "FarmScheduler; writes BENCH_engine.json)")
    ap.add_argument("--engine-out", default="BENCH_engine.json")
    ap.add_argument("--scale", action="store_true",
                    help="only run the NoW-scale scheduler stress gate "
                         "(overhead curve + determinism + churn; writes "
                         "BENCH_scale.json)")
    ap.add_argument("--scale-services", type=int, default=200)
    ap.add_argument("--scale-tasks", type=int, default=100_000)
    ap.add_argument("--scale-out", default="BENCH_scale.json")
    ap.add_argument("--contention", action="store_true",
                    help="only run the sharded-repository contention "
                         "gate (1/8/32 shards under real threads + "
                         "shards=1 trace identity; writes "
                         "BENCH_contention.json)")
    ap.add_argument("--contention-services", default="32,96",
                    help="service counts for --contention (the gate "
                         "applies at the top count)")
    ap.add_argument("--contention-per-service", type=int, default=128)
    ap.add_argument("--contention-repeats", type=int, default=2)
    ap.add_argument("--contention-out", default="BENCH_contention.json")
    ap.add_argument("--obs", action="store_true",
                    help="only run the telemetry-spine gate (recorder "
                         "overhead vs tracing-disabled + the hetero-NoW "
                         "Perfetto trace; writes BENCH_obs.json and "
                         "BENCH_obs_trace.json)")
    ap.add_argument("--obs-tasks", type=int, default=10_000)
    ap.add_argument("--obs-repeats", type=int, default=2)
    ap.add_argument("--obs-out", default="BENCH_obs.json")
    ap.add_argument("--obs-trace-out", default="BENCH_obs_trace.json")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with the full table: also export a Chrome "
                         "trace-event JSON of the heterogeneous-NoW "
                         "scenario to PATH (load in Perfetto)")
    ap.add_argument("--wire", action="store_true",
                    help="only run the transport wire gate (inproc/shm/"
                         "proc/tcp µs-per-task + socket payload bytes; "
                         "writes BENCH_wire.json)")
    ap.add_argument("--wire-tasks", type=int, default=100)
    ap.add_argument("--wire-repeats", type=int, default=2)
    ap.add_argument("--wire-out", default="BENCH_wire.json")
    ap.add_argument("--autotune", action="store_true",
                    help="only run the kernel-autotuning gate (farm "
                         "sweep speedup + sim:// determinism + dispatch "
                         "overhead; writes BENCH_autotune.json)")
    ap.add_argument("--autotune-kernels", default="xla_flash,mamba",
                    help="comma-separated kernels for the real sweep")
    ap.add_argument("--autotune-reps", type=int, default=3)
    ap.add_argument("--autotune-out", default="BENCH_autotune.json")
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-inflight", type=int, default=2)
    ap.add_argument("--transport", choices=("inproc", "proc"),
                    default="inproc",
                    help="farm backend for --compare-batched (proc = one "
                         "OS process per service)")
    args = ap.parse_args()
    if args.compare_batched:
        compare_batched(args)
        return
    if args.engine_overhead:
        run_engine_overhead(args)
        return
    if args.scale:
        run_scale(args)
        return
    if args.contention:
        run_contention(args)
        return
    if args.obs:
        run_obs(args)
        return
    if args.wire:
        run_wire(args)
        return
    if args.autotune:
        run_autotune(args)
        return

    from benchmarks import (autotune, contention, elasticity,
                            engine_overhead, farm_scalability,
                            fault_tolerance, heterogeneous_now, kernels,
                            load_balance, multi_tenant, normal_form,
                            observability, scale, wire)

    print("name,us_per_call,derived")
    for mod in (farm_scalability, load_balance, fault_tolerance, normal_form,
                elasticity, heterogeneous_now, multi_tenant, engine_overhead,
                scale, contention, wire, observability, autotune, kernels):
        for name, us, derived in mod.bench():
            print(f"{name},{us:.1f},{derived}")

    if args.trace:
        from benchmarks.observability import export_hetero_trace

        info = export_hetero_trace(args.trace)
        print(f"trace/{args.trace},{info['events']},"
              f"tracks={info['service_tracks']} "
              f"types={len(info['event_types'])}")

    # roofline summary (if the dry-run grid has been produced)
    dr = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    files = glob.glob(os.path.join(dr, "*.json"))
    if files:
        from repro.launch.roofline import analyze_cell

        ok = skipped = err = 0
        fits = 0
        for f in files:
            rec = json.load(open(f))
            if rec.get("status") == "ok":
                ok += 1
                row = analyze_cell(rec)
                if row and row["fits_hbm"]:
                    fits += 1
            elif rec.get("status") == "skipped":
                skipped += 1
            else:
                err += 1
        print(f"dryrun/cells_ok,{ok},skipped={skipped} errors={err} "
              f"fits_hbm={fits}/{ok}")


if __name__ == "__main__":
    main()
