"""Autotuning gate: the farm tunes the kernels the farm serves with.

Three claims, three gates (``pass`` in ``BENCH_autotune.json``):

- **speedup** — a real successive-halving sweep (farm-dispatched over
  inproc services, then the winner and the hand-picked default re-timed
  *serially* so concurrency noise can't flatter the figure) finds a
  config ≥ ``--speedup-floor`` (default 1.15×) faster than the default
  on at least one kernel/shape on the CPU XLA path;
- **determinism** — the same-seed ``sim://`` sweep with the scripted
  cost model, run twice on fresh clusters, picks byte-identical winners
  (JSON-serialized summaries compare equal);
- **overhead** — a cache-hit ``best_config`` dispatch probe costs
  ≤ ``--overhead-pct`` (default 3%) of the tuned kernel's call time.

CPU timings are NOT TPU performance — the point is that the machinery
(sweep → cache → dispatch) demonstrably moves a real clock on the
backend it runs on.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import LookupService, Service  # noqa: E402
from repro.sim import SimCluster  # noqa: E402
from repro.tune import (DEFAULTS, KernelTuner, TuningCache,  # noqa: E402
                        best_config, measure_candidate, set_cache)

#: (kernel, shape) pairs for the real CPU sweep — XLA-path kernels only
#: (the Pallas kernels interpret on CPU; timing them times the emulator).
REAL_SPECS = {
    "xla_flash": {"B": 1, "Sq": 512, "Skv": 512, "H": 8, "K": 2, "D": 64,
                  "Dv": 64},
    "mamba": {"b": 2, "s": 1024, "d": 64, "n": 16},
}

#: shape for the sim:// determinism sweep (scripted cost model)
SIM_SPEC = ("xla_flash", {"B": 1, "Sq": 1024, "Skv": 1024, "H": 8, "K": 2,
                          "D": 64, "Dv": 64})


def _serial_us(kernel, shape, config, reps) -> float:
    """Re-time one config in-process, no farm in the loop."""
    res = measure_candidate({"kernel": kernel, "shape": shape,
                             "config": config, "reps": reps, "seed": 0})
    assert res["ok"], res.get("error")
    return res["us"]


def bench_real(kernels, *, services=2, reps=3, final_reps=5) -> dict:
    """Farm-sweep each kernel on inproc services, then serially re-time
    winner vs default; returns per-kernel rows + the best speedup."""
    lookup = LookupService()
    for i in range(services):
        Service(lookup, service_id=f"tune-{i}").start()
    rows = {}
    cache = TuningCache()  # in-memory; the sweep is the product here
    with KernelTuner(lookup, cache=cache, max_batch=4) as tuner:
        for kernel in kernels:
            shape = REAL_SPECS[kernel]
            t0 = time.perf_counter()
            r = tuner.tune(kernel, shape, base_reps=1, full_reps=reps,
                           finalists=2, save=False)
            sweep_s = time.perf_counter() - t0
            tuned_us = _serial_us(kernel, shape, r.config, final_reps)
            default_us = _serial_us(kernel, shape, r.default_config,
                                    final_reps)
            rows[kernel] = {
                "shape": shape, "config": r.config,
                "default_config": r.default_config,
                "tuned_us": round(tuned_us, 1),
                "default_us": round(default_us, 1),
                "speedup": round(default_us / tuned_us, 4),
                "candidates": r.candidates, "pruned": r.pruned,
                "failed": r.failed, "rounds": r.rounds,
                "sweep_s": round(sweep_s, 2),
            }
    return rows


def bench_sim_determinism(seed=3) -> dict:
    """Two fresh same-seed sim:// sweeps must pick identical winners."""
    kernel, shape = SIM_SPEC

    def sweep():
        with SimCluster(speed_factors=[1, 1, 2, 4], seed=7) as cluster:
            with cluster.make_scheduler(max_batch=4) as sched:
                tuner = KernelTuner(scheduler=sched, cache=TuningCache())
                r = tuner.tune(kernel, shape, cost_model="scripted",
                               seed=seed)
            return json.dumps(r.summary(), sort_keys=True)

    a, b = sweep(), sweep()
    return {"kernel": kernel, "seed": seed, "identical": a == b,
            "winner": json.loads(a)["config"],
            "scripted_us": json.loads(a)["us"]}


def bench_overhead(real_rows, *, probes=20_000) -> dict:
    """Cache-hit ``best_config`` cost as a % of the tuned kernel call."""
    kernel = max(real_rows, key=lambda k: real_rows[k]["speedup"])
    row = real_rows[kernel]
    cache = TuningCache()
    cache.put(kernel, row["shape"], "float32", "xla", row["config"], 1.0,
              save=False)
    set_cache(cache)
    try:
        default = DEFAULTS[kernel]
        best_config(kernel, row["shape"], "float32", "xla", default)  # warm
        t0 = time.perf_counter()
        for _ in range(probes):
            best_config(kernel, row["shape"], "float32", "xla", default)
        lookup_us = (time.perf_counter() - t0) / probes * 1e6
    finally:
        set_cache(None)
    return {"kernel": kernel, "lookup_us": round(lookup_us, 4),
            "kernel_us": row["tuned_us"],
            "overhead_pct": round(lookup_us / row["tuned_us"] * 100, 4)}


def bench_autotune(*, kernels=("xla_flash", "mamba"), services=2, reps=3,
                   speedup_floor=1.15, overhead_pct=3.0, seed=3) -> dict:
    real = bench_real(kernels, services=services, reps=reps)
    sim = bench_sim_determinism(seed)
    overhead = bench_overhead(real)
    best = max(r["speedup"] for r in real.values())
    gates = {
        "best_speedup": best,
        "speedup_floor": speedup_floor,
        "speedup_ok": best >= speedup_floor,
        "sim_deterministic": sim["identical"],
        "dispatch_overhead_pct": overhead["overhead_pct"],
        "overhead_ceiling_pct": overhead_pct,
        "overhead_ok": overhead["overhead_pct"] <= overhead_pct,
    }
    return {
        "benchmark": "autotune",
        "config": {"kernels": list(kernels), "services": services,
                   "reps": reps, "seed": seed},
        "real": real, "sim": sim, "overhead": overhead, "gates": gates,
        "pass": (gates["speedup_ok"] and gates["sim_deterministic"]
                 and gates["overhead_ok"]),
    }


def bench() -> list[tuple[str, float, str]]:
    """Harness entry (``benchmarks/run.py`` table) — reduced sweep."""
    r = bench_autotune(kernels=("mamba",), reps=2)
    rows = []
    for kernel, row in r["real"].items():
        rows.append((f"autotune/{kernel}_tuned", row["tuned_us"],
                     f"default={row['default_us']:.0f}us "
                     f"speedup={row['speedup']:.2f}x"))
    rows.append(("autotune/dispatch_overhead",
                 r["overhead"]["lookup_us"],
                 f"pct_of_kernel={r['overhead']['overhead_pct']:.3f}%"))
    rows.append(("autotune/sim_scripted", r["sim"]["scripted_us"],
                 f"deterministic={r['sim']['identical']} pass={r['pass']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernels", default="xla_flash,mamba",
                    help="comma-separated XLA-path kernels to real-sweep")
    ap.add_argument("--services", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3,
                    help="final-round reps for the real sweep")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--speedup-floor", type=float, default=1.15)
    ap.add_argument("--overhead-pct", type=float, default=3.0)
    ap.add_argument("--out", default=None,
                    help="write results to this JSON file "
                         "(e.g. BENCH_autotune.json)")
    args = ap.parse_args(argv)

    result = bench_autotune(kernels=tuple(args.kernels.split(",")),
                            services=args.services, reps=args.reps,
                            speedup_floor=args.speedup_floor,
                            overhead_pct=args.overhead_pct, seed=args.seed)
    for kernel, row in result["real"].items():
        print(f"autotune/{kernel},{row['tuned_us']:.1f},"
              f"default={row['default_us']:.1f}us "
              f"speedup={row['speedup']:.2f}x "
              f"cfg={json.dumps(row['config'], sort_keys=True)}")
    g = result["gates"]
    print(f"autotune/dispatch_overhead,"
          f"{result['overhead']['lookup_us']:.3f},"
          f"pct={g['dispatch_overhead_pct']:.3f}% "
          f"ceiling={g['overhead_ceiling_pct']}%")
    print(f"autotune/sim_deterministic,{int(g['sim_deterministic'])},"
          f"winner={json.dumps(result['sim']['winner'], sort_keys=True)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.out}")
    assert result["pass"], (
        f"autotune gate failed: best speedup {g['best_speedup']:.2f}x "
        f"(floor {g['speedup_floor']}x); "
        f"sim deterministic={g['sim_deterministic']}; "
        f"dispatch overhead {g['dispatch_overhead_pct']:.3f}% "
        f"(ceiling {g['overhead_ceiling_pct']}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
